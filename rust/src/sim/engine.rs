//! The unified discrete-event cluster simulator.
//!
//! One event engine drives every evaluation scenario over any
//! [`ServingSystem`]: a seeded, deterministic event queue carries request
//! arrivals, decode steps, periodic scaling decisions, and instance
//! failure/recovery events. The three scenarios are thin configurations:
//!
//! - [`FixedBatchScenario`] — fixed-batch decode-loop evaluation (Figs
//!   8/9/10/12); [`super::decode_sim::evaluate_fixed_batch`] wraps it.
//! - [`AutoscaleScenario`] — trace-driven diurnal autoscaling at a fixed
//!   decision interval (Fig 11); [`super::autoscale_sim::AutoscaleSim`]
//!   wraps it.
//! - [`FailureScenario`] — failure injection: kill and restore MoE/GPU
//!   capacity mid-trace while bursty arrivals keep flowing, and measure
//!   SLO attainment through the system's replica re-placement.
//!
//! Seeded-determinism contract: running any scenario twice with the same
//! seed (and a freshly built system) yields **bit-identical** metrics.
//! Event-queue ties break on insertion order, every random draw flows
//! from one seeded [`Rng`], and no wall-clock time enters the loop. The
//! golden regression tests pin this contract.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::baselines::system::ServingSystem;
use crate::config::serving::Slo;
use crate::metrics::{GpuHours, TpotStats};
use crate::util::rng::Rng;
use crate::workload::arrivals::{ArrivalProcess, BurstyPoisson};
use crate::workload::lengths::LengthModel;
use crate::workload::trace::DiurnalTrace;

// ------------------------------------------------------------------ events

/// What happens when an event fires.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Sample the next one-second arrival window (keeps the queue
    /// bounded instead of pre-pushing every arrival over the horizon).
    ArrivalWindow,
    /// One request joins the in-flight pool with this many output tokens.
    Arrival { output_tokens: u32 },
    /// Execute one decode step over the current in-flight batch.
    DecodeStep,
    /// Periodic scaling decision over the demand estimate.
    ScalingDecision,
    /// `gpus` GPUs drop out of the pool for `downtime` seconds.
    Failure { gpus: usize, downtime: f64 },
    /// Previously failed GPUs return to the pool.
    Recovery { gpus: usize },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Simulated time, seconds from scenario start.
    pub time: f64,
    pub kind: EventKind,
}

#[derive(Debug)]
struct Entry {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Earliest time first; ties break on insertion order so replays
        // are bit-identical regardless of heap internals.
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Deterministic min-time event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at `time` (seconds). NaN times are rejected.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        assert!(!time.is_nan(), "event time must not be NaN");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { time, seq, kind }));
    }

    /// Pop the earliest event (insertion order on ties).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| Event {
            time: e.time,
            kind: e.kind,
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

// --------------------------------------------------------------- scenarios

/// Fixed-batch decode-loop evaluation (Fig 8): `steps` decode steps at a
/// constant total batch, distributional TPOT metrics out.
#[derive(Clone, Debug)]
pub struct FixedBatchScenario {
    pub batch: usize,
    pub slo: Slo,
    pub steps: usize,
}

/// Trace-driven autoscaling (Fig 11): replay a diurnal demand trace
/// against the system's scaling policy at a fixed decision interval.
#[derive(Clone, Debug)]
pub struct AutoscaleScenario {
    /// Decision interval, seconds (paper: 900).
    pub interval: f64,
    /// Decode-token demand per request (≈ average output length).
    pub tokens_per_request: f64,
    pub slo: Slo,
    pub trace: DiurnalTrace,
}

/// One planned outage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailurePlan {
    /// Failure time, seconds from scenario start.
    pub at: f64,
    /// GPUs lost (per-side instance budget for disaggregated systems).
    pub gpus: usize,
    /// Seconds until the capacity returns.
    pub downtime: f64,
}

/// Failure injection: bursty request arrivals drive a live decode loop
/// while planned outages remove capacity; the system re-places replicas
/// (reconfigures on the surviving pool) at each failure/recovery and at
/// the periodic scaling decisions.
#[derive(Clone, Debug)]
pub struct FailureScenario {
    pub slo: Slo,
    /// Mean request arrival rate (req/s) when no rate trace is given.
    pub arrival_rate: f64,
    /// Mean output tokens per request (drives demand = rate × tokens).
    pub tokens_per_request: f64,
    /// Scenario horizon, seconds.
    pub horizon: f64,
    /// Scaling-decision cadence, seconds.
    pub decision_interval: f64,
    /// Short-term arrival burstiness (Gamma cv², see `workload::arrivals`).
    pub burst_cv2: f64,
    /// Optional diurnal rate envelope; when set, the instantaneous arrival
    /// rate follows `trace.rate_at(t)` (its `mean_rate` is in req/s) and
    /// failures land mid-trace.
    pub rate_trace: Option<DiurnalTrace>,
    pub failures: Vec<FailurePlan>,
}

impl FailureScenario {
    /// Constant-rate scenario with 60 s decisions and mild burstiness.
    pub fn new(slo: Slo, arrival_rate: f64, tokens_per_request: f64, horizon: f64) -> Self {
        FailureScenario {
            slo,
            arrival_rate,
            tokens_per_request,
            horizon,
            decision_interval: 60.0,
            burst_cv2: 0.3,
            rate_trace: None,
            failures: Vec::new(),
        }
    }

    /// Add one outage.
    pub fn with_failure(mut self, at: f64, gpus: usize, downtime: f64) -> Self {
        self.failures.push(FailurePlan { at, gpus, downtime });
        self
    }
}

/// Any scenario, for the single-entry [`run`] API.
#[derive(Clone, Debug)]
pub enum Scenario {
    FixedBatch(FixedBatchScenario),
    Autoscale(AutoscaleScenario),
    FailureInjection(FailureScenario),
}

// ----------------------------------------------------------------- results

/// Result of evaluating one system at one batch size.
#[derive(Clone, Debug)]
pub struct FixedBatchResult {
    pub system: &'static str,
    pub batch: usize,
    pub config_label: String,
    pub gpus: usize,
    /// Whether the system found an SLO-feasible config at all.
    pub feasible: bool,
    pub tpot_mean: f64,
    pub tpot_p99: f64,
    /// Tokens/s/GPU at the measured mean TPOT.
    pub tpg: f64,
    /// Mean straggler activated-expert count across steps.
    pub a_max_mean: f64,
    pub slo_attainment: f64,
}

/// Per-interval scaling record.
#[derive(Clone, Debug)]
pub struct IntervalRecord {
    pub t_start: f64,
    pub demand: f64,
    pub gpus: usize,
    pub label: String,
    pub feasible: bool,
}

/// Full autoscaling run result.
#[derive(Clone, Debug)]
pub struct AutoscaleResult {
    pub system: &'static str,
    pub intervals: Vec<IntervalRecord>,
    pub gpu_hours: f64,
    /// Fraction of intervals where the policy found an SLO-feasible
    /// configuration.
    pub feasible_fraction: f64,
    pub min_gpus: usize,
    pub max_gpus: usize,
}

/// Failure-injection run result.
#[derive(Clone, Debug)]
pub struct FailureResult {
    pub system: &'static str,
    /// Decode steps executed.
    pub steps: usize,
    pub completed_requests: usize,
    pub generated_tokens: usize,
    /// Per-step TPOT distribution.
    pub tpot: TpotStats,
    /// Fraction of decode steps meeting the SLO (1.0 with zero steps).
    pub slo_attainment: f64,
    /// Attainment restricted to steps while capacity was degraded.
    pub attainment_degraded: f64,
    /// Attainment restricted to steps on the healthy pool.
    pub attainment_healthy: f64,
    /// Decode steps that ran while capacity was degraded.
    pub degraded_steps: usize,
    /// Fraction of scaling/re-placement decisions that were feasible.
    pub feasible_fraction: f64,
    /// Failure + recovery re-placements performed.
    pub reconfigurations: usize,
    pub gpu_hours: f64,
    pub min_gpus: usize,
    pub max_gpus: usize,
}

/// Outcome of [`run`], tagged by scenario.
#[derive(Clone, Debug)]
pub enum ScenarioOutcome {
    FixedBatch(FixedBatchResult),
    Autoscale(AutoscaleResult),
    FailureInjection(FailureResult),
}

// --------------------------------------------------------------- execution

/// Run any scenario for any system from one entry point.
pub fn run<S: ServingSystem + ?Sized>(
    system: &mut S,
    scenario: &Scenario,
    seed: u64,
) -> ScenarioOutcome {
    match scenario {
        Scenario::FixedBatch(sc) => ScenarioOutcome::FixedBatch(fixed_batch(system, sc, seed)),
        Scenario::Autoscale(sc) => ScenarioOutcome::Autoscale(autoscale(system, sc)),
        Scenario::FailureInjection(sc) => {
            ScenarioOutcome::FailureInjection(failure_injection(system, sc, seed))
        }
    }
}

/// Fixed-batch decode evaluation: configure once, then chain decode-step
/// events — each step schedules the next at `t + TPOT`.
pub fn fixed_batch<S: ServingSystem + ?Sized>(
    system: &mut S,
    sc: &FixedBatchScenario,
    seed: u64,
) -> FixedBatchResult {
    let cfg = system.configure(sc.batch, sc.slo);
    let feasible = cfg.is_some();
    let mut rng = Rng::seed_from_u64(seed);
    let mut queue = EventQueue::new();
    if sc.steps > 0 {
        queue.push(0.0, EventKind::DecodeStep);
    }
    let mut stats = TpotStats::new();
    let mut a_sum = 0.0;
    let mut done = 0usize;
    while let Some(ev) = queue.pop() {
        debug_assert!(matches!(ev.kind, EventKind::DecodeStep));
        let out = system.step(sc.batch, &mut rng);
        stats.push(out.tpot);
        a_sum += out.a_max as f64;
        done += 1;
        if done < sc.steps {
            queue.push(ev.time + out.tpot, EventKind::DecodeStep);
        }
    }
    let gpus = system.gpus();
    let tpot_mean = stats.mean();
    FixedBatchResult {
        system: system.name(),
        batch: sc.batch,
        config_label: system.label(),
        gpus,
        feasible,
        tpot_mean,
        tpot_p99: stats.p99(),
        tpg: sc.batch as f64 / tpot_mean / gpus.max(1) as f64,
        a_max_mean: a_sum / sc.steps.max(1) as f64,
        slo_attainment: stats.attainment(sc.slo.tpot),
    }
}

/// Trace-driven autoscaling: chained scaling-decision events walk the
/// trace at the decision interval.
pub fn autoscale<S: ServingSystem + ?Sized>(
    system: &mut S,
    sc: &AutoscaleScenario,
) -> AutoscaleResult {
    let horizon = sc.trace.config.hours * 3600.0;
    let mut queue = EventQueue::new();
    if horizon > 0.0 {
        queue.push(0.0, EventKind::ScalingDecision);
    }
    let mut records = Vec::new();
    let mut hours = GpuHours::new();
    let mut feasible_count = 0usize;
    while let Some(ev) = queue.pop() {
        debug_assert!(matches!(ev.kind, EventKind::ScalingDecision));
        let t = ev.time;
        let t_end = (t + sc.interval).min(horizon);
        let req_rate = sc.trace.mean_rate_in(t, t_end);
        let token_demand = req_rate * sc.tokens_per_request;
        let cfg = system.configure_for_demand(token_demand.max(1.0), sc.slo);
        let feasible = cfg.is_some();
        if feasible {
            feasible_count += 1;
        }
        let gpus = system.gpus();
        hours.add(gpus, t_end - t);
        records.push(IntervalRecord {
            t_start: t,
            demand: token_demand,
            gpus,
            label: system.label(),
            feasible,
        });
        if t_end < horizon {
            queue.push(t_end, EventKind::ScalingDecision);
        }
    }
    let n = records.len().max(1);
    AutoscaleResult {
        system: system.name(),
        gpu_hours: hours.total(),
        feasible_fraction: feasible_count as f64 / n as f64,
        min_gpus: records.iter().map(|r| r.gpus).min().unwrap_or(0),
        max_gpus: records.iter().map(|r| r.gpus).max().unwrap_or(0),
        intervals: records,
    }
}

/// Failure injection: arrivals, decode steps, scaling decisions, and
/// planned outages all flow through one event queue.
pub fn failure_injection<S: ServingSystem + ?Sized>(
    system: &mut S,
    sc: &FailureScenario,
    seed: u64,
) -> FailureResult {
    assert!(sc.horizon > 0.0 && sc.decision_interval > 0.0);
    let mut rng = Rng::seed_from_u64(seed);
    let mut queue = EventQueue::new();

    // Initial sizing decision, then the periodic cadence.
    queue.push(0.0, EventKind::ScalingDecision);

    // Planned outages.
    for f in &sc.failures {
        queue.push(
            f.at,
            EventKind::Failure {
                gpus: f.gpus,
                downtime: f.downtime,
            },
        );
    }

    // The arrival stream is sampled lazily, one 1-second window at a
    // time (`ArrivalWindow` events), through the bursty (Cox) process;
    // request output lengths come from the ShareGPT-like length model
    // centered on `tokens_per_request`. A dedicated arrivals RNG keeps
    // the stream independent of how many decode steps interleave, so
    // determinism holds without pre-materializing the whole horizon.
    let bursty = BurstyPoisson::new(sc.burst_cv2);
    let lengths = LengthModel::with_means(16.0, sc.tokens_per_request.max(1.0), 0.6);
    let mut arrival_rng = Rng::seed_from_u64(seed ^ 0x4152_5256_4956_414C);
    queue.push(0.0, EventKind::ArrivalWindow);

    // Demand estimate for sizing decisions (offered load).
    let demand_at = |t0: f64, t1: f64| -> f64 {
        let rate = match &sc.rate_trace {
            Some(trace) => trace.mean_rate_in(t0, t1),
            None => sc.arrival_rate,
        };
        (rate * sc.tokens_per_request).max(1.0)
    };

    // Live state.
    let mut in_flight: Vec<u32> = Vec::new();
    let mut step_pending = false;
    let mut failed_gpus = 0usize;
    let mut stats = TpotStats::new();
    let mut steps = 0usize;
    let mut ok_steps = 0usize;
    let mut degraded_steps = 0usize;
    let mut degraded_ok = 0usize;
    let mut completed = 0usize;
    let mut generated = 0usize;
    let mut decisions = 0usize;
    let mut feasible_decisions = 0usize;
    let mut reconfigurations = 0usize;
    let mut hours = GpuHours::new();
    let mut last_account = 0.0f64;
    let mut min_gpus = usize::MAX;
    let mut max_gpus = 0usize;

    fn account(hours: &mut GpuHours, last: &mut f64, now: f64, gpus: usize) {
        hours.add(gpus, (now - *last).max(0.0));
        *last = now;
    }
    fn track(gpus: usize, min_g: &mut usize, max_g: &mut usize) {
        if gpus > 0 {
            *min_g = (*min_g).min(gpus);
            *max_g = (*max_g).max(gpus);
        }
    }

    while let Some(ev) = queue.pop() {
        if ev.time > sc.horizon {
            break;
        }
        match ev.kind {
            EventKind::ArrivalWindow => {
                let dt = (sc.horizon - ev.time).min(1.0);
                if dt > 0.0 {
                    let rate = match &sc.rate_trace {
                        Some(trace) => trace.rate_at(ev.time),
                        None => sc.arrival_rate,
                    };
                    let n = bursty.arrivals(&mut arrival_rng, rate, dt);
                    for _ in 0..n {
                        let at = ev.time + arrival_rng.f64() * dt;
                        let output_tokens = lengths.sample(&mut arrival_rng).output_tokens;
                        queue.push(at, EventKind::Arrival { output_tokens });
                    }
                    let next = ev.time + dt;
                    if next < sc.horizon {
                        queue.push(next, EventKind::ArrivalWindow);
                    }
                }
            }
            EventKind::Arrival { output_tokens } => {
                in_flight.push(output_tokens.max(1));
                if !step_pending {
                    step_pending = true;
                    queue.push(ev.time, EventKind::DecodeStep);
                }
            }
            EventKind::DecodeStep => {
                if in_flight.is_empty() {
                    step_pending = false;
                    continue;
                }
                let batch = in_flight.len();
                let out = system.step(batch, &mut rng);
                stats.push(out.tpot);
                steps += 1;
                generated += batch;
                let ok = out.tpot <= sc.slo.tpot;
                if ok {
                    ok_steps += 1;
                }
                if failed_gpus > 0 {
                    degraded_steps += 1;
                    if ok {
                        degraded_ok += 1;
                    }
                }
                let before = in_flight.len();
                for r in in_flight.iter_mut() {
                    *r -= 1;
                }
                in_flight.retain(|&r| r > 0);
                completed += before - in_flight.len();
                queue.push(ev.time + out.tpot, EventKind::DecodeStep);
            }
            EventKind::ScalingDecision => {
                account(&mut hours, &mut last_account, ev.time, system.gpus());
                let t_end = (ev.time + sc.decision_interval).min(sc.horizon);
                let cfg = system.configure_for_demand(demand_at(ev.time, t_end), sc.slo);
                decisions += 1;
                if cfg.is_some() {
                    feasible_decisions += 1;
                }
                track(system.gpus(), &mut min_gpus, &mut max_gpus);
                if t_end < sc.horizon {
                    queue.push(t_end, EventKind::ScalingDecision);
                }
            }
            EventKind::Failure { gpus, downtime } => {
                account(&mut hours, &mut last_account, ev.time, system.gpus());
                failed_gpus += gpus;
                system.fail_gpus(gpus);
                // Re-placement on the surviving pool.
                let t_end = (ev.time + sc.decision_interval).min(sc.horizon);
                let cfg = system.reconfigure_for_pool(demand_at(ev.time, t_end), sc.slo);
                decisions += 1;
                reconfigurations += 1;
                if cfg.is_some() {
                    feasible_decisions += 1;
                }
                track(system.gpus(), &mut min_gpus, &mut max_gpus);
                queue.push(ev.time + downtime, EventKind::Recovery { gpus });
            }
            EventKind::Recovery { gpus } => {
                account(&mut hours, &mut last_account, ev.time, system.gpus());
                failed_gpus = failed_gpus.saturating_sub(gpus);
                system.restore_gpus(gpus);
                let t_end = (ev.time + sc.decision_interval).min(sc.horizon);
                let cfg = system.reconfigure_for_pool(demand_at(ev.time, t_end), sc.slo);
                decisions += 1;
                reconfigurations += 1;
                if cfg.is_some() {
                    feasible_decisions += 1;
                }
                track(system.gpus(), &mut min_gpus, &mut max_gpus);
            }
        }
    }
    account(&mut hours, &mut last_account, sc.horizon, system.gpus());

    let att = |ok: usize, total: usize| {
        if total == 0 {
            1.0
        } else {
            ok as f64 / total as f64
        }
    };
    FailureResult {
        system: system.name(),
        steps,
        completed_requests: completed,
        generated_tokens: generated,
        slo_attainment: att(ok_steps, steps),
        attainment_degraded: att(degraded_ok, degraded_steps),
        attainment_healthy: att(ok_steps - degraded_ok, steps - degraded_steps),
        degraded_steps,
        feasible_fraction: att(feasible_decisions, decisions),
        reconfigurations,
        gpu_hours: hours.total(),
        min_gpus: if min_gpus == usize::MAX { 0 } else { min_gpus },
        max_gpus,
        tpot: stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{JanusSystem, MegaScaleInfer, ServingSystem, SgLang, XDeepServe};
    use crate::config::hardware::{autoscale_pool, paper_testbed};
    use crate::config::models::deepseek_v2;
    use crate::routing::gate::ExpertPopularity;
    use crate::workload::trace::{DiurnalTrace, TraceConfig};

    #[test]
    fn queue_orders_by_time_then_insertion() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::DecodeStep);
        q.push(1.0, EventKind::ScalingDecision);
        q.push(1.0, EventKind::DecodeStep);
        q.push(0.5, EventKind::Recovery { gpus: 1 });
        assert_eq!(q.len(), 4);
        let order: Vec<Event> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order[0].kind, EventKind::Recovery { gpus: 1 });
        // Tie at t=1.0 resolves in insertion order.
        assert_eq!(order[1].kind, EventKind::ScalingDecision);
        assert_eq!(order[2].kind, EventKind::DecodeStep);
        assert_eq!(order[3].kind, EventKind::DecodeStep);
        assert!(q.is_empty());
    }

    fn janus(n_max: usize, seed: u64) -> JanusSystem {
        JanusSystem::build(
            deepseek_v2(),
            autoscale_pool(),
            &ExpertPopularity::Uniform,
            n_max,
            seed,
        )
    }

    #[test]
    fn unified_run_covers_all_scenarios_for_all_systems() {
        let model = deepseek_v2();
        let hw = paper_testbed();
        let pop = ExpertPopularity::Uniform;
        let fixed = Scenario::FixedBatch(FixedBatchScenario {
            batch: 64,
            slo: Slo::from_ms(200.0),
            steps: 5,
        });
        let mut cfg = TraceConfig::one_day();
        cfg.hours = 2.0;
        cfg.mean_rate = 12.0;
        let auto = Scenario::Autoscale(AutoscaleScenario {
            interval: 900.0,
            tokens_per_request: 256.0,
            slo: Slo::from_ms(200.0),
            trace: DiurnalTrace::generate(cfg),
        });
        let fail = Scenario::FailureInjection(
            FailureScenario::new(Slo::from_ms(200.0), 2.0, 32.0, 120.0)
                .with_failure(40.0, 8, 30.0),
        );
        let mut j = JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 1);
        let mut s = SgLang::build(model.clone(), hw.clone(), &pop, 2);
        let mut m = MegaScaleInfer::build(model.clone(), hw.clone(), &pop, 16, 3);
        let mut x = XDeepServe::build(model, hw, &pop, 32, 4);
        let systems: Vec<&mut dyn ServingSystem> = vec![&mut j, &mut s, &mut m, &mut x];
        for sys in systems {
            for sc in [&fixed, &auto, &fail] {
                match run(sys, sc, 9) {
                    ScenarioOutcome::FixedBatch(r) => {
                        assert!(r.tpot_mean > 0.0, "{}", r.system);
                        assert!(r.gpus > 0, "{}", r.system);
                    }
                    ScenarioOutcome::Autoscale(r) => {
                        assert_eq!(r.intervals.len(), 8, "{}", r.system);
                        assert!(r.gpu_hours > 0.0, "{}", r.system);
                    }
                    ScenarioOutcome::FailureInjection(r) => {
                        assert!(r.steps > 0, "{}", r.system);
                        assert_eq!(r.reconfigurations, 2, "{}", r.system);
                        assert!(r.gpu_hours > 0.0, "{}", r.system);
                    }
                }
            }
        }
    }

    #[test]
    fn failure_injection_degrades_and_recovers() {
        // Kill 28 of the 32 per-side instance budget: the survivors cannot
        // seat every DeepSeek-V2 expert (n_e_min = 6 > 4), so re-placement
        // must report infeasibility until recovery — while the decode loop
        // keeps serving on the emergency layout.
        let sc = FailureScenario::new(Slo::from_ms(200.0), 4.0, 64.0, 600.0)
            .with_failure(120.0, 28, 240.0);
        let mut sys = janus(32, 7);
        let r = failure_injection(&mut sys, &sc, 11);
        assert!(r.steps > 0);
        assert!(r.completed_requests > 0);
        assert_eq!(r.reconfigurations, 2);
        assert!(r.degraded_steps > 0, "outage window saw no steps");
        assert!(
            r.feasible_fraction < 1.0,
            "losing 28/32 instances must make some decision infeasible"
        );
        assert!(r.feasible_fraction > 0.0, "healthy decisions must succeed");
        assert_eq!(r.tpot.count(), r.steps);
        assert!(r.min_gpus <= r.max_gpus && r.max_gpus > 0);
        // The pool is healthy again after recovery: a fresh decision on the
        // restored budget is feasible.
        assert!(sys.configure_for_demand(256.0, Slo::from_ms(200.0)).is_some());
    }

    #[test]
    fn failure_scenario_is_bit_deterministic() {
        let sc = FailureScenario::new(Slo::from_ms(200.0), 3.0, 48.0, 300.0)
            .with_failure(60.0, 12, 120.0);
        let run_once = || {
            let mut sys = janus(16, 21);
            let r = failure_injection(&mut sys, &sc, 33);
            (
                r.steps,
                r.completed_requests,
                r.generated_tokens,
                r.tpot.mean().to_bits(),
                r.tpot.p99().to_bits(),
                r.gpu_hours.to_bits(),
                r.slo_attainment.to_bits(),
            )
        };
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn fixed_batch_matches_legacy_decode_loop() {
        // The engine path must be numerically identical to the pre-engine
        // decode loop: configure once, then step with a seeded RNG.
        let sc = FixedBatchScenario {
            batch: 128,
            slo: Slo::from_ms(200.0),
            steps: 15,
        };
        let mut a = janus(16, 5);
        let engine_r = fixed_batch(&mut a, &sc, 17);
        let mut b = janus(16, 5);
        let legacy = {
            let cfg = b.configure(sc.batch, sc.slo);
            assert!(cfg.is_some());
            let mut rng = Rng::seed_from_u64(17);
            let mut stats = TpotStats::new();
            for _ in 0..sc.steps {
                stats.push(b.step(sc.batch, &mut rng).tpot);
            }
            (stats.mean().to_bits(), stats.p99().to_bits())
        };
        assert_eq!(engine_r.tpot_mean.to_bits(), legacy.0);
        assert_eq!(engine_r.tpot_p99.to_bits(), legacy.1);
    }
}
