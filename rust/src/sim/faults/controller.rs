//! Run-time fault state: which windows are open, the aggregate
//! straggler/transient effect, and per-event accounting.

use crate::util::rng::Rng;

use super::plan::{FaultKind, FaultPlan, RetryConfig, ScriptedFault};
use super::stats::{FaultEvent, FaultStats};
use super::{DegradationPolicy, FAULT_STREAM_SALT};

/// What a `ServingSystem` did to recover from one fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryAction {
    /// True when the system repaired only the fault's blast radius
    /// (placement surgery) instead of a whole-pool reconfiguration.
    pub narrowed: bool,
    /// Whether the post-recovery state is feasible (SLO-solvable and,
    /// for narrowed recoveries, with no expert dropped).
    pub feasible: bool,
    /// Experts re-seated onto surviving instances.
    pub moved_experts: usize,
    /// Experts dropped (no surviving replica and no free slot).
    pub dropped_experts: usize,
    /// Modeled weight/KV transfer time of the repair, seconds.
    pub transfer_secs: f64,
    /// Replicas copied in the background (post-crash re-replication
    /// restoring the replication invariant on the survivors).
    pub re_replicated_experts: usize,
    /// Background weight-copy time, seconds — charged as a stall but
    /// off the critical repair path.
    pub background_secs: f64,
    /// When `Some(r)`, the system restored *full* service `r` seconds
    /// after the fault (every expert live again, replication invariant
    /// restored): the engine ends the degradation window early and the
    /// event's MTTR is `r` (capped at the fault window). `None` keeps
    /// the legacy degraded-for-the-whole-window semantics.
    pub restored_secs: Option<f64>,
}

impl RecoveryAction {
    /// Legacy whole-pool `fail_gpus` + `reconfigure_for_pool` recovery.
    pub fn whole_pool(feasible: bool) -> Self {
        RecoveryAction {
            narrowed: false,
            feasible,
            moved_experts: 0,
            dropped_experts: 0,
            transfer_secs: 0.0,
            re_replicated_experts: 0,
            background_secs: 0.0,
            restored_secs: None,
        }
    }

    /// Narrowed recovery that re-seated `moved` experts (and dropped
    /// the ones with no surviving replica and no free slot).
    pub fn expert_replacement(moved: usize, dropped: usize, transfer_secs: f64) -> Self {
        RecoveryAction {
            narrowed: true,
            feasible: dropped == 0,
            moved_experts: moved,
            dropped_experts: dropped,
            transfer_secs,
            re_replicated_experts: 0,
            background_secs: 0.0,
            restored_secs: None,
        }
    }

    /// Narrowed recovery that changed no placement (pure degradation:
    /// straggler, transient window, attention-side bookkeeping).
    pub fn degradation() -> Self {
        RecoveryAction {
            narrowed: true,
            feasible: true,
            moved_experts: 0,
            dropped_experts: 0,
            transfer_secs: 0.0,
            re_replicated_experts: 0,
            background_secs: 0.0,
            restored_secs: None,
        }
    }

    /// Attach background re-replication work: `copies` replicas staged
    /// onto survivors over `background_secs` of modeled transfer.
    pub fn with_re_replication(mut self, copies: usize, background_secs: f64) -> Self {
        self.re_replicated_experts = copies;
        self.background_secs = background_secs;
        self
    }

    /// Declare full service restored `secs` after the fault, ending
    /// the degradation window early (availability-aware recoveries).
    pub fn with_service_restored(mut self, secs: f64) -> Self {
        self.restored_secs = Some(secs.max(0.0));
        self
    }
}

/// Per-run fault state machine. Owns the materialized fault timeline,
/// the dedicated fault RNG, and the aggregate view the engine reads on
/// every decode step (`straggler()`, `step_extra()`, `shedding()`).
#[derive(Clone, Debug)]
pub struct FaultController {
    timeline: Vec<ScriptedFault>,
    active: Vec<bool>,
    policy: DegradationPolicy,
    retry: RetryConfig,
    rng: Rng,
    /// Max slowdown factor over the active straggler windows (1.0 when
    /// none is open).
    straggler: f64,
    /// Max per-attempt failure probability over the active transient
    /// windows (0.0 when none is open).
    transient_prob: f64,
    /// Pending repair stall (KV migration, weight transfer) charged to
    /// the next decode step.
    stall: f64,
    active_count: usize,
    degraded_since: Option<f64>,
    /// Aggregate accounting, surfaced via `FailureResult`.
    pub stats: FaultStats,
}

impl FaultController {
    /// Materialize `plan` over `[0, horizon)`. The RNG is salted with
    /// [`FAULT_STREAM_SALT`] so fault draws never perturb the arrival,
    /// class, or decode streams.
    pub fn new(plan: &FaultPlan, seed: u64, horizon: f64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ FAULT_STREAM_SALT);
        let mut timeline = plan.scripted.clone();
        if let Some(s) = &plan.stochastic {
            s.materialize(&mut rng, horizon, &mut timeline);
        }
        let active = vec![false; timeline.len()];
        FaultController {
            timeline,
            active,
            policy: plan.policy.unwrap_or_else(DegradationPolicy::from_env),
            retry: plan.retry,
            rng,
            straggler: 1.0,
            transient_prob: 0.0,
            stall: 0.0,
            active_count: 0,
            degraded_since: None,
            stats: FaultStats::default(),
        }
    }

    /// The materialized fault windows (scripted then stochastic), in
    /// plan order; the engine schedules one `Fault`/`FaultClear` event
    /// pair per entry by index.
    pub fn timeline(&self) -> &[ScriptedFault] {
        &self.timeline
    }

    pub fn fault_at(&self, idx: usize) -> ScriptedFault {
        self.timeline[idx]
    }

    pub fn policy(&self) -> DegradationPolicy {
        self.policy
    }

    pub fn retry(&self) -> RetryConfig {
        self.retry
    }

    /// Open fault window `idx` at time `now`.
    pub fn on_fault(&mut self, idx: usize, now: f64) {
        if self.active[idx] {
            return;
        }
        self.active[idx] = true;
        self.active_count += 1;
        if self.active_count == 1 {
            self.degraded_since = Some(now);
        }
        self.recompute_aggregates();
    }

    /// Close fault window `idx` at time `now`.
    pub fn on_clear(&mut self, idx: usize, now: f64) {
        if !self.active[idx] {
            return;
        }
        self.active[idx] = false;
        self.active_count -= 1;
        if self.active_count == 0 {
            if let Some(since) = self.degraded_since.take() {
                self.stats.degraded_time += (now - since).max(0.0);
            }
        }
        self.recompute_aggregates();
    }

    fn recompute_aggregates(&mut self) {
        let mut straggler = 1.0f64;
        let mut prob = 0.0f64;
        for (f, active) in self.timeline.iter().zip(&self.active) {
            if !active {
                continue;
            }
            match f.kind {
                FaultKind::Straggler { factor } => straggler = straggler.max(factor),
                FaultKind::TransientComm { fail_prob } => prob = prob.max(fail_prob),
                FaultKind::InstanceCrash { .. } | FaultKind::AttentionHostLoss { .. } => {}
            }
        }
        self.straggler = straggler;
        self.transient_prob = prob;
    }

    /// Record the recovery the serving system performed for one fault
    /// event. `duration` is the fault's full window length. Per-event
    /// MTTR: a recovery that declared `restored_secs` repaired in that
    /// time (capped at the window); a *feasible* narrowed recovery
    /// repaired in its transfer time; everything else — whole-pool
    /// recoveries, and narrowed recoveries that dropped experts (the
    /// serving state stays broken until the resource returns) — costs
    /// the full window.
    #[allow(clippy::too_many_arguments)]
    pub fn note_recovery(
        &mut self,
        at: f64,
        kind: &'static str,
        action: RecoveryAction,
        duration: f64,
        evicted: usize,
        migrated_kv_tokens: u64,
        recompute_tokens: u64,
    ) {
        self.stats.migrated_kv_tokens += migrated_kv_tokens;
        self.stats.recompute_tokens += recompute_tokens;
        self.stats.re_replicated_experts += action.re_replicated_experts as u64;
        self.stats.background_transfer_secs += action.background_secs;
        self.stats.events.push(FaultEvent {
            at,
            kind,
            narrowed: action.narrowed,
            feasible: action.feasible,
            moved_experts: action.moved_experts,
            dropped_experts: action.dropped_experts,
            transfer_secs: action.transfer_secs,
            mttr: match action.restored_secs {
                Some(r) => r.min(duration),
                None if action.narrowed && action.feasible => action.transfer_secs,
                None => duration,
            },
            evicted,
            migrated_kv_tokens,
            recompute_tokens,
        });
    }

    /// An availability-aware recovery finished restoring full service
    /// before fault window `idx`'s scripted end: close the degradation
    /// window now. The eventual `FaultClear` still runs the system-side
    /// restore (`on_clear` is idempotent). No-op if the window already
    /// closed.
    pub fn on_early_repair(&mut self, idx: usize, now: f64) {
        if self.active[idx] {
            self.stats.early_repairs += 1;
            self.on_clear(idx, now);
        }
    }

    /// Charge a repair stall (weight transfer, KV migration) against
    /// the next decode step.
    pub fn add_stall(&mut self, secs: f64) {
        if secs > 0.0 {
            self.stall += secs;
        }
    }

    /// Whether fresh arrivals are shed right now (`shed` policy inside
    /// any open fault window).
    pub fn shedding(&self) -> bool {
        self.policy == DegradationPolicy::Shed && self.active_count > 0
    }

    /// Whether any fault window is open (the degraded condition the
    /// engine folds into per-class degraded-window accounting).
    pub fn fault_active(&self) -> bool {
        self.active_count > 0
    }

    /// Current aggregate slowdown factor for the expert side.
    pub fn straggler(&self) -> f64 {
        self.straggler
    }

    /// Extra per-step latency: pending repair stalls plus transient
    /// dispatch/combine retries (bounded deterministic retry, timeout +
    /// exponential backoff per failed attempt). Called once per decode
    /// step only while a plan is installed; performs RNG draws only
    /// inside transient windows.
    pub fn step_extra(&mut self) -> f64 {
        // tidy:hot-path:begin faults-step-extra
        let mut extra = self.stall;
        self.stall = 0.0;
        if self.transient_prob > 0.0 {
            let mut backoff = self.retry.backoff;
            let mut attempt = 0u32;
            while attempt < self.retry.max_retries && self.rng.f64() < self.transient_prob {
                let penalty = self.retry.timeout + backoff;
                extra += penalty;
                self.stats.retry_rounds += 1;
                self.stats.retry_latency += penalty;
                backoff *= 2.0;
                attempt += 1;
            }
        }
        extra
        // tidy:hot-path:end
    }

    /// Close any window still open at the horizon and hand the
    /// accounting back.
    pub fn finish(mut self, horizon: f64) -> FaultStats {
        if let Some(since) = self.degraded_since.take() {
            self.stats.degraded_time += (horizon - since).max(0.0);
        }
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan::StochasticFaults;
    use super::*;

    #[test]
    fn recovery_action_ctors() {
        let wp = RecoveryAction::whole_pool(true);
        assert!(!wp.narrowed && wp.feasible);
        let er = RecoveryAction::expert_replacement(5, 0, 0.25);
        assert!(er.narrowed && er.feasible && er.moved_experts == 5);
        let dropped = RecoveryAction::expert_replacement(3, 2, 0.1);
        assert!(!dropped.feasible, "dropped experts make the event infeasible");
        assert!(RecoveryAction::degradation().narrowed);
    }

    #[test]
    fn windows_track_degraded_time_and_aggregates() {
        let plan = FaultPlan::new()
            .with_straggler(10.0, 20.0, 2.0)
            .with_straggler(15.0, 10.0, 3.0)
            .with_transient_comm(40.0, 5.0, 0.5)
            .with_policy(DegradationPolicy::Shed);
        let mut ctl = FaultController::new(&plan, 7, 100.0);
        assert_eq!(ctl.timeline().len(), 3);
        assert_eq!(ctl.straggler(), 1.0);
        assert!(!ctl.fault_active() && !ctl.shedding());

        ctl.on_fault(0, 10.0);
        assert!(ctl.fault_active() && ctl.shedding());
        assert_eq!(ctl.straggler(), 2.0);
        ctl.on_fault(1, 15.0);
        assert_eq!(ctl.straggler(), 3.0, "max over open windows");
        ctl.on_clear(1, 25.0);
        assert_eq!(ctl.straggler(), 2.0);
        ctl.on_clear(0, 30.0);
        assert_eq!(ctl.straggler(), 1.0);
        assert!(!ctl.fault_active());

        ctl.on_fault(2, 40.0);
        let stats = ctl.finish(100.0);
        // [10, 30) closed + [40, 100) open at horizon.
        assert!((stats.degraded_time - 80.0).abs() < 1e-12);
    }

    #[test]
    fn step_extra_drains_stall_and_bounds_retries() {
        let plan = FaultPlan::new().with_transient_comm(0.0, 10.0, 1.0);
        let mut ctl = FaultController::new(&plan, 11, 100.0);
        ctl.add_stall(0.5);
        // Window closed: stall drains, no retry draws.
        assert!((ctl.step_extra() - 0.5).abs() < 1e-12);
        assert_eq!(ctl.step_extra(), 0.0);
        assert_eq!(ctl.stats.retry_rounds, 0);

        // fail_prob = 1.0 ⇒ exactly max_retries failures per step:
        // (timeout+b) + (timeout+2b) + (timeout+4b).
        ctl.on_fault(0, 0.0);
        let r = ctl.retry();
        let expect = 3.0 * r.timeout + 7.0 * r.backoff;
        assert!((ctl.step_extra() - expect).abs() < 1e-12);
        assert_eq!(ctl.stats.retry_rounds, u64::from(r.max_retries));
        assert!((ctl.stats.retry_latency - expect).abs() < 1e-12);
    }

    #[test]
    fn same_seed_same_timeline_and_draws() {
        let plan = FaultPlan::new()
            .with_instance_crash(5.0, 30.0, 1)
            .with_stochastic(StochasticFaults {
                rate_per_hour: 720.0,
                mean_duration: 10.0,
                kinds: vec![FaultKind::TransientComm { fail_prob: 0.5 }],
            })
            .with_policy(DegradationPolicy::Off);
        let mut a = FaultController::new(&plan, 42, 600.0);
        let mut b = FaultController::new(&plan, 42, 600.0);
        assert_eq!(a.timeline(), b.timeline());
        assert!(a.timeline().len() > 1, "stochastic stream materialized");
        a.on_fault(1, a.fault_at(1).at);
        b.on_fault(1, b.fault_at(1).at);
        for _ in 0..100 {
            assert_eq!(a.step_extra().to_bits(), b.step_extra().to_bits());
        }
    }

    #[test]
    fn note_recovery_accumulates_stats() {
        let plan = FaultPlan::new().with_instance_crash(1.0, 60.0, 0);
        let mut ctl = FaultController::new(&plan, 3, 100.0);
        ctl.note_recovery(
            1.0,
            "instance-crash",
            RecoveryAction::expert_replacement(4, 0, 0.2),
            60.0,
            2,
            128,
            64,
        );
        ctl.note_recovery(
            1.0,
            "attention-host-loss",
            RecoveryAction::whole_pool(true),
            60.0,
            0,
            0,
            0,
        );
        assert_eq!(ctl.stats.events.len(), 2);
        assert!((ctl.stats.events[0].mttr - 0.2).abs() < 1e-12, "narrowed mttr");
        assert!((ctl.stats.events[1].mttr - 60.0).abs() < 1e-12, "whole-pool mttr");
        assert_eq!(ctl.stats.migrated_kv_tokens, 128);
        assert_eq!(ctl.stats.recompute_tokens, 64);
        assert!((ctl.stats.mttr_mean() - 30.1).abs() < 1e-12);
    }

    #[test]
    fn dropped_experts_cost_the_full_window() {
        // A narrowed recovery that dropped experts leaves the serving
        // state broken until the instance returns: its MTTR is the
        // whole fault window, not the (possibly zero) transfer time.
        let plan = FaultPlan::new().with_instance_crash(1.0, 60.0, 0);
        let mut ctl = FaultController::new(&plan, 5, 100.0);
        ctl.note_recovery(
            1.0,
            "instance-crash",
            RecoveryAction::expert_replacement(0, 3, 0.0),
            60.0,
            0,
            0,
            0,
        );
        assert!((ctl.stats.events[0].mttr - 60.0).abs() < 1e-12);
        assert!(!ctl.stats.events[0].feasible);
    }

    #[test]
    fn restored_secs_overrides_and_caps_mttr() {
        let plan = FaultPlan::new().with_instance_crash(1.0, 60.0, 0);
        let mut ctl = FaultController::new(&plan, 5, 100.0);
        let action = RecoveryAction::expert_replacement(4, 0, 0.2)
            .with_re_replication(3, 0.15)
            .with_service_restored(0.35);
        assert_eq!(action.re_replicated_experts, 3);
        ctl.note_recovery(1.0, "instance-crash", action, 60.0, 0, 0, 0);
        assert!((ctl.stats.events[0].mttr - 0.35).abs() < 1e-12);
        assert_eq!(ctl.stats.re_replicated_experts, 3);
        assert!((ctl.stats.background_transfer_secs - 0.15).abs() < 1e-12);
        // Declared restore times never exceed the fault window.
        ctl.note_recovery(
            1.0,
            "instance-crash",
            RecoveryAction::whole_pool(true).with_service_restored(120.0),
            60.0,
            0,
            0,
            0,
        );
        assert!((ctl.stats.events[1].mttr - 60.0).abs() < 1e-12);
    }

    #[test]
    fn early_repair_closes_the_window_once() {
        let plan = FaultPlan::new()
            .with_instance_crash(10.0, 50.0, 0)
            .with_policy(DegradationPolicy::Replica);
        let mut ctl = FaultController::new(&plan, 9, 100.0);
        ctl.on_fault(0, 10.0);
        assert!(ctl.fault_active());
        ctl.on_early_repair(0, 12.5);
        assert!(!ctl.fault_active(), "early repair closes the window");
        assert_eq!(ctl.stats.early_repairs, 1);
        // The scripted clear (and repeated repairs) are no-ops.
        ctl.on_early_repair(0, 13.0);
        ctl.on_clear(0, 60.0);
        assert_eq!(ctl.stats.early_repairs, 1);
        let stats = ctl.finish(100.0);
        assert!(
            (stats.degraded_time - 2.5).abs() < 1e-12,
            "degraded only [10, 12.5): {}",
            stats.degraded_time
        );
    }
}
