//! `sim::faults` — the fine-grained fault plane of the failure scenario.
//!
//! [`super::engine::FailureScenario`]'s legacy schedule kills and
//! restores whole anonymous GPU counts. Disaggregation changes the
//! blast radius — when one MoE instance dies only its hosted experts
//! must re-place, and when an attention host dies only its KV caches
//! are at stake — so this module adds a deterministic fault plane with
//! four fault kinds ([`FaultKind`]):
//!
//! - **instance crash** — a *named* MoE instance dies. Systems with
//!   per-instance expert placement re-place only the dead instance's
//!   experts (transfer cost charged through `comm::cost`); everyone
//!   else falls back to the legacy whole-pool
//!   `fail_gpus`/`reconfigure_for_pool` path.
//! - **attention-host loss** — in-flight requests on the dead host
//!   either migrate their KV at a modeled cost (charged as a stall on
//!   the next decode step) or re-enter admission as recompute prefill,
//!   reusing the KV-aware preemption accounting.
//! - **degraded GPU / straggler** — a per-GPU slowdown factor flowing
//!   into `perfmodel::tpot`'s expert term, so AEBS and the baseline
//!   schedulers all see the straggler.
//! - **transient dispatch/combine faults** — bounded deterministic
//!   retry with timeout + exponential backoff, charged as extra comm
//!   latency on every decode step inside the fault window.
//!
//! A [`FaultPlan`] composes scripted faults with an optional
//! seeded-stochastic stream. The stochastic stream (and the transient
//! retry draws) run on a dedicated RNG salted with
//! [`FAULT_STREAM_SALT`], so a scenario without a plan performs zero
//! fault-RNG draws and stays bit-identical to the legacy path.
//!
//! Graceful degradation is selected by [`DegradationPolicy`]
//! (`JANUS_FAULTS`): `off` re-places every lost expert and never
//! touches admission; `shed` additionally sheds fresh arrivals during
//! each re-placement window; `replica` routes around the loss — only
//! sole-replica experts re-place (and when no replica survives and no
//! slot is free, the expert is dropped and the event reported
//! infeasible).

pub mod controller;
pub mod plan;
pub mod stats;

pub use controller::{FaultController, RecoveryAction};
pub use plan::{FaultKind, FaultPlan, RetryConfig, ScriptedFault, StochasticFaults};
pub use stats::{FaultEvent, FaultStats};

/// Environment variable selecting the default degradation policy for
/// fault plans that do not pin one (`off` | `shed` | `replica`).
pub const FAULTS_ENV: &str = "JANUS_FAULTS";

/// Seed salt for the dedicated fault RNG ("FAULTRNG" bytes): the
/// stochastic fault stream and transient-retry draws live on their own
/// stream, so runs without a [`FaultPlan`] draw nothing from it and
/// every other stream (arrivals, classes, decode) is untouched by the
/// fault plane.
pub const FAULT_STREAM_SALT: u64 = 0x4641_554C_5452_4E47;

/// How the serving stack degrades while a fault is being repaired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradationPolicy {
    /// Full re-placement, no admission changes (the baseline).
    Off,
    /// Shed fresh arrivals during each re-placement window, so the
    /// surviving pool only serves already-admitted work.
    Shed,
    /// Route to surviving replicas: only sole-replica experts re-place,
    /// shrinking the repair transfer (and its degraded window).
    Replica,
}

impl DegradationPolicy {
    pub const ALL: [DegradationPolicy; 3] = [
        DegradationPolicy::Off,
        DegradationPolicy::Shed,
        DegradationPolicy::Replica,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => Some(DegradationPolicy::Off),
            "shed" => Some(DegradationPolicy::Shed),
            "replica" | "route" => Some(DegradationPolicy::Replica),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DegradationPolicy::Off => "off",
            DegradationPolicy::Shed => "shed",
            DegradationPolicy::Replica => "replica",
        }
    }

    /// Default for plans that do not pin a policy: `JANUS_FAULTS`
    /// (unset/unparsable ⇒ `Off`). Golden surfaces pin a policy
    /// explicitly instead of resolving the environment.
    pub fn from_env() -> Self {
        std::env::var(FAULTS_ENV)
            .ok()
            .and_then(|s| Self::parse(&s))
            .unwrap_or(DegradationPolicy::Off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_all_spellings() {
        assert_eq!(DegradationPolicy::parse("off"), Some(DegradationPolicy::Off));
        assert_eq!(DegradationPolicy::parse("SHED"), Some(DegradationPolicy::Shed));
        assert_eq!(
            DegradationPolicy::parse(" replica "),
            Some(DegradationPolicy::Replica)
        );
        assert_eq!(DegradationPolicy::parse("nope"), None);
        for p in DegradationPolicy::ALL {
            assert_eq!(DegradationPolicy::parse(p.name()), Some(p));
        }
    }
}
