//! Fault-plan configuration: scripted faults, the seeded-stochastic
//! stream, and transient-retry tuning.

use crate::util::rng::Rng;

use super::DegradationPolicy;

/// One kind of fine-grained fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// A named MoE instance dies; only its hosted experts need a new
    /// home (systems without per-instance placement fall back to the
    /// whole-pool path).
    InstanceCrash { instance: u32 },
    /// An attention host dies. `migrate_kv` moves the host's resident
    /// KV to survivors at a modeled transfer cost; otherwise every
    /// in-flight request on the host re-enters admission as recompute
    /// prefill (the KV-aware preemption accounting).
    AttentionHostLoss { host: u32, migrate_kv: bool },
    /// A degraded GPU slows the expert side by `factor` (≥ 1) for the
    /// fault's duration.
    Straggler { factor: f64 },
    /// Transient dispatch/combine faults: each decode step inside the
    /// window retries with probability `fail_prob` per attempt, paying
    /// timeout + exponential backoff as extra comm latency.
    TransientComm { fail_prob: f64 },
}

impl FaultKind {
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::InstanceCrash { .. } => "instance-crash",
            FaultKind::AttentionHostLoss { .. } => "attention-host-loss",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::TransientComm { .. } => "transient-comm",
        }
    }

    fn validate(&self) -> Result<(), String> {
        match *self {
            FaultKind::Straggler { factor } => {
                if !factor.is_finite() || factor < 1.0 {
                    return Err(format!(
                        "straggler factor must be finite and >= 1, got {factor}"
                    ));
                }
            }
            FaultKind::TransientComm { fail_prob } => {
                if !fail_prob.is_finite() || !(0.0..=1.0).contains(&fail_prob) || fail_prob == 0.0 {
                    return Err(format!(
                        "transient fail_prob must be in (0, 1], got {fail_prob}"
                    ));
                }
            }
            FaultKind::InstanceCrash { .. } | FaultKind::AttentionHostLoss { .. } => {}
        }
        Ok(())
    }
}

/// One scheduled fault window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScriptedFault {
    /// Fault time, seconds from scenario start.
    pub at: f64,
    /// Window length, seconds (the fault clears at `at + duration`).
    pub duration: f64,
    pub kind: FaultKind,
}

impl ScriptedFault {
    fn validate(&self, horizon: f64) -> Result<(), String> {
        if !self.at.is_finite() || self.at < 0.0 {
            return Err(format!(
                "fault time must be finite and non-negative, got {}s",
                self.at
            ));
        }
        if !self.duration.is_finite() || self.duration <= 0.0 {
            return Err(format!(
                "fault duration must be positive finite seconds, got {}s",
                self.duration
            ));
        }
        if self.at >= horizon {
            return Err(format!(
                "fault at {}s lies beyond the {horizon}s horizon",
                self.at
            ));
        }
        self.kind.validate()
    }
}

/// A seeded-stochastic fault stream: Poisson fault arrivals at
/// `rate_per_hour`, exponential window lengths of mean `mean_duration`
/// seconds, cycling through `kinds`. Materialized once per run on the
/// dedicated fault RNG stream.
#[derive(Clone, Debug, PartialEq)]
pub struct StochasticFaults {
    pub rate_per_hour: f64,
    pub mean_duration: f64,
    pub kinds: Vec<FaultKind>,
}

impl StochasticFaults {
    fn validate(&self) -> Result<(), String> {
        if !self.rate_per_hour.is_finite() || self.rate_per_hour <= 0.0 {
            return Err(format!(
                "stochastic rate_per_hour must be positive finite, got {}",
                self.rate_per_hour
            ));
        }
        if !self.mean_duration.is_finite() || self.mean_duration <= 0.0 {
            return Err(format!(
                "stochastic mean_duration must be positive finite seconds, got {}",
                self.mean_duration
            ));
        }
        if self.kinds.is_empty() {
            return Err("stochastic stream needs at least one fault kind".to_string());
        }
        for k in &self.kinds {
            k.validate()?;
        }
        Ok(())
    }

    /// Draw the stream over `[0, horizon)` into `out` (exponential
    /// inter-arrivals, exponential durations, kinds cycling in order).
    pub fn materialize(&self, rng: &mut Rng, horizon: f64, out: &mut Vec<ScriptedFault>) {
        let rate = self.rate_per_hour / 3600.0;
        let mut t = rng.exponential(rate);
        let mut next_kind = 0usize;
        while t < horizon {
            let duration = rng.exponential(1.0 / self.mean_duration).max(1e-3);
            out.push(ScriptedFault {
                at: t,
                duration,
                kind: self.kinds[next_kind % self.kinds.len()],
            });
            next_kind += 1;
            t += rng.exponential(rate);
        }
    }
}

/// Bounded deterministic retry for transient dispatch/combine faults.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryConfig {
    /// Retry attempts per decode step inside a transient window.
    pub max_retries: u32,
    /// Per-failed-attempt timeout charged as comm latency, seconds.
    pub timeout: f64,
    /// First backoff delay, seconds; doubles per failed attempt.
    pub backoff: f64,
}

impl Default for RetryConfig {
    fn default() -> Self {
        RetryConfig {
            max_retries: 3,
            timeout: 2e-3,
            backoff: 1e-3,
        }
    }
}

impl RetryConfig {
    fn validate(&self) -> Result<(), String> {
        if !self.timeout.is_finite() || self.timeout < 0.0 {
            return Err(format!(
                "retry timeout must be finite non-negative seconds, got {}",
                self.timeout
            ));
        }
        if !self.backoff.is_finite() || self.backoff < 0.0 {
            return Err(format!(
                "retry backoff must be finite non-negative seconds, got {}",
                self.backoff
            ));
        }
        Ok(())
    }
}

/// The composed fault plane of one failure-injection run.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultPlan {
    /// Scripted fault windows.
    pub scripted: Vec<ScriptedFault>,
    /// Optional seeded-stochastic stream merged on top.
    pub stochastic: Option<StochasticFaults>,
    /// Degradation policy; `None` resolves `JANUS_FAULTS` at run time
    /// (golden surfaces pin one explicitly).
    pub policy: Option<DegradationPolicy>,
    /// Transient-retry tuning.
    pub retry: RetryConfig,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Whether the plan schedules nothing at all (such a plan must run
    /// bit-identically to no plan).
    pub fn is_empty(&self) -> bool {
        self.scripted.is_empty() && self.stochastic.is_none()
    }

    pub fn with_policy(mut self, policy: DegradationPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    pub fn with_fault(mut self, at: f64, duration: f64, kind: FaultKind) -> Self {
        self.scripted.push(ScriptedFault { at, duration, kind });
        self
    }

    pub fn with_instance_crash(self, at: f64, duration: f64, instance: u32) -> Self {
        self.with_fault(at, duration, FaultKind::InstanceCrash { instance })
    }

    pub fn with_attention_host_loss(
        self,
        at: f64,
        duration: f64,
        host: u32,
        migrate_kv: bool,
    ) -> Self {
        self.with_fault(at, duration, FaultKind::AttentionHostLoss { host, migrate_kv })
    }

    pub fn with_straggler(self, at: f64, duration: f64, factor: f64) -> Self {
        self.with_fault(at, duration, FaultKind::Straggler { factor })
    }

    pub fn with_transient_comm(self, at: f64, duration: f64, fail_prob: f64) -> Self {
        self.with_fault(at, duration, FaultKind::TransientComm { fail_prob })
    }

    pub fn with_stochastic(mut self, stream: StochasticFaults) -> Self {
        self.stochastic = Some(stream);
        self
    }

    /// Reject degenerate plans with a descriptive message (the engine
    /// wraps this in `ScenarioError::InvalidFaultPlan`).
    pub fn validate(&self, horizon: f64) -> Result<(), String> {
        for f in &self.scripted {
            f.validate(horizon)?;
        }
        if let Some(s) = &self.stochastic {
            s.validate()?;
        }
        self.retry.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_rejects_degenerate_plans() {
        let ok = FaultPlan::new().with_instance_crash(10.0, 30.0, 2);
        assert!(ok.validate(100.0).is_ok());
        assert!(ok.validate(10.0).is_err(), "at == horizon is past it");
        let neg = FaultPlan::new().with_instance_crash(-1.0, 30.0, 2);
        assert!(neg.validate(100.0).is_err());
        let zero = FaultPlan::new().with_straggler(5.0, 0.0, 2.0);
        assert!(zero.validate(100.0).is_err());
        let factor = FaultPlan::new().with_straggler(5.0, 10.0, 0.5);
        assert!(factor.validate(100.0).is_err());
        let prob = FaultPlan::new().with_transient_comm(5.0, 10.0, 0.0);
        assert!(prob.validate(100.0).is_err());
        let mut bad_retry = FaultPlan::new().with_instance_crash(1.0, 2.0, 0);
        bad_retry.retry.timeout = f64::NAN;
        assert!(bad_retry.validate(100.0).is_err());
        let empty_stream = FaultPlan::new().with_stochastic(StochasticFaults {
            rate_per_hour: 1.0,
            mean_duration: 10.0,
            kinds: vec![],
        });
        assert!(empty_stream.validate(100.0).is_err());
    }

    #[test]
    fn stochastic_stream_is_deterministic_and_bounded() {
        let s = StochasticFaults {
            rate_per_hour: 3600.0, // one per second on average
            mean_duration: 5.0,
            kinds: vec![
                FaultKind::Straggler { factor: 2.0 },
                FaultKind::TransientComm { fail_prob: 0.5 },
            ],
        };
        let mut a = Vec::new();
        let mut b = Vec::new();
        s.materialize(&mut Rng::seed_from_u64(9), 60.0, &mut a);
        s.materialize(&mut Rng::seed_from_u64(9), 60.0, &mut b);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed, same stream");
        assert!(a.iter().all(|f| f.at < 60.0 && f.duration > 0.0));
        // Kinds cycle in order.
        assert_eq!(a[0].kind.label(), "straggler");
        if a.len() > 1 {
            assert_eq!(a[1].kind.label(), "transient-comm");
        }
    }

    #[test]
    fn empty_plan_is_empty() {
        assert!(FaultPlan::new().is_empty());
        assert!(!FaultPlan::new().with_straggler(1.0, 2.0, 3.0).is_empty());
    }
}
