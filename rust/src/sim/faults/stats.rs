//! Per-fault-event accounting carried into
//! [`crate::sim::engine::FailureResult`].

/// What one fault event did to the serving stack.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    /// Fault time, seconds from scenario start.
    pub at: f64,
    /// [`super::FaultKind::label`] of the fault.
    pub kind: &'static str,
    /// Whether the system performed a narrowed recovery (placement
    /// surgery / deployment patch) instead of the whole-pool fallback.
    pub narrowed: bool,
    /// Whether the recovery left an SLO-feasible (and fully-replicated)
    /// serving state.
    pub feasible: bool,
    /// Experts re-seated onto survivors.
    pub moved_experts: usize,
    /// Experts dropped because no replica survived and no slot was free
    /// (the expert-drop fallback).
    pub dropped_experts: usize,
    /// Modeled weight/KV transfer time of the repair, seconds.
    pub transfer_secs: f64,
    /// Mean-time-to-repair of this event: the declared restore time for
    /// availability-aware recoveries (capped at the window), the
    /// transfer time for feasible narrowed recoveries, the full fault
    /// window for whole-pool recoveries and for narrowed recoveries
    /// that dropped experts.
    pub mttr: f64,
    /// In-flight requests evicted back to admission.
    pub evicted: usize,
    /// KV tokens migrated to surviving hosts.
    pub migrated_kv_tokens: u64,
    /// KV tokens to rebuild as recompute prefill.
    pub recompute_tokens: u64,
}

/// Aggregate fault accounting of one failure-injection run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// One record per fault event, in firing order.
    pub events: Vec<FaultEvent>,
    /// KV tokens queued for recompute prefill across all evictions.
    pub recompute_tokens: u64,
    /// KV tokens discarded at eviction (work thrown away).
    pub lost_tokens: u64,
    /// KV tokens migrated at modeled cost instead of recomputed.
    pub migrated_kv_tokens: u64,
    /// Fresh arrivals shed during re-placement windows (`shed` policy).
    pub shed_requests: u64,
    /// Failed dispatch/combine attempts retried inside transient
    /// windows.
    pub retry_rounds: u64,
    /// Total extra comm latency charged by transient retries, seconds.
    pub retry_latency: f64,
    /// Seconds with at least one fault window active (legacy whole-pool
    /// outage windows are added by the engine), clamped to the horizon.
    pub degraded_time: f64,
    /// Replicas copied onto survivors by post-crash re-replication
    /// (availability-aware placement restoring the replication
    /// invariant).
    pub re_replicated_experts: u64,
    /// Total background weight-copy time (re-replication, prefetch
    /// staging), seconds — charged as stalls off the critical path.
    pub background_transfer_secs: f64,
    /// Fault windows closed early because the recovery restored full
    /// service before the scripted clear.
    pub early_repairs: u64,
}

impl FaultStats {
    /// Mean time-to-repair across fault events (0.0 with no events).
    pub fn mttr_mean(&self) -> f64 {
        if self.events.is_empty() {
            return 0.0;
        }
        self.events.iter().map(|e| e.mttr).sum::<f64>() / self.events.len() as f64
    }

    /// Fraction of the horizon with no degraded window active.
    pub fn availability(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            return 1.0;
        }
        (1.0 - self.degraded_time / horizon).clamp(0.0, 1.0)
    }

    /// Fault events recovered by narrowed (non-whole-pool) recovery.
    pub fn narrowed_events(&self) -> usize {
        self.events.iter().filter(|e| e.narrowed).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(mttr: f64, narrowed: bool) -> FaultEvent {
        FaultEvent {
            at: 0.0,
            kind: "instance-crash",
            narrowed,
            feasible: true,
            moved_experts: 0,
            dropped_experts: 0,
            transfer_secs: 0.0,
            mttr,
            evicted: 0,
            migrated_kv_tokens: 0,
            recompute_tokens: 0,
        }
    }

    #[test]
    fn mttr_and_availability() {
        let mut s = FaultStats::default();
        assert_eq!(s.mttr_mean(), 0.0);
        assert_eq!(s.availability(100.0), 1.0);
        s.events.push(event(2.0, true));
        s.events.push(event(10.0, false));
        assert!((s.mttr_mean() - 6.0).abs() < 1e-12);
        assert_eq!(s.narrowed_events(), 1);
        s.degraded_time = 25.0;
        assert!((s.availability(100.0) - 0.75).abs() < 1e-12);
        s.degraded_time = 1e9;
        assert_eq!(s.availability(100.0), 0.0, "clamped");
        assert_eq!(s.availability(0.0), 1.0, "degenerate horizon");
    }
}
