//! Discrete-event evaluation harness.
//!
//! - [`engine`] — the unified discrete-event cluster simulator: one
//!   seeded event queue (request arrivals, decode steps, scaling
//!   decisions, instance failure/recovery) drives every scenario for
//!   every [`crate::baselines::ServingSystem`].
//! - [`decode_sim`] — fixed-batch decode-loop evaluation (drives Figs
//!   8/9/10/12), a thin wrapper over [`engine::FixedBatchScenario`].
//! - [`autoscale_sim`] — trace-driven scaling over a diurnal trace with a
//!   periodic decision interval (drives Fig 11), a thin wrapper over
//!   [`engine::AutoscaleScenario`]. The scenario runs a live,
//!   arrival-driven decode loop with a bounded admission queue and
//!   continuous batching (per-token join/leave), reporting per-request
//!   admission delay, TTFT, and per-token TPOT percentiles alongside
//!   GPU-hours.
//!
//! - [`admission`] — the pluggable admission subsystem behind both
//!   arrival-driven scenarios: a deterministic [`admission::AdmissionPolicy`]
//!   trait with FIFO (legacy-identical), SLO-class priority (starvation
//!   aging), and KV-aware (chunked prefill, KV-occupancy accounting,
//!   preemption) implementations, selected per scenario or via
//!   `JANUS_ADMISSION`.
//!
//! - [`faults`] — the fine-grained fault plane: a [`faults::FaultPlan`]
//!   composes scripted and seeded-stochastic fault windows (instance
//!   crash, attention-host loss, straggler, transient dispatch/combine
//!   faults) on a dedicated RNG stream, with per-system narrowed
//!   recovery, graceful-degradation policies (`JANUS_FAULTS`), and
//!   per-fault-event MTTR/availability accounting in
//!   [`engine::FailureResult`].
//!
//! - [`tracegen`] — the canonical pinned trace bundle for the
//!   observability plane: a fixed cell grid (fixed-batch lineup,
//!   autoscale ramp under both scaling modes, golden fault plan) run
//!   through [`sweep::run_cells_traced`] and serialized to
//!   Chrome-trace JSON + metrics TSV. Byte-identical across reruns,
//!   thread counts, and env matrix legs; `bin/trace` writes it to disk.
//!
//! - [`sweep`] — the deterministic parallel sweep engine: independent
//!   (system ctor × scenario × seed) cells drained by scoped workers
//!   over one atomic claim index (claims are chunked — K cells per
//!   `fetch_add`, `JANUS_CHUNK` overridable), with slot-per-cell result
//!   collection so the output is bit-identical for any worker count and
//!   chunk size (figures, golden sweeps, and `bench_sim` all run their
//!   grids through it).
//!
//! Failure injection ([`engine::FailureScenario`]) lives directly in the
//! engine: planned outages remove capacity mid-trace and the run measures
//! SLO attainment through the system's replica re-placement.
//!
//! The arrival-driven scenario entry points (autoscale, failure
//! injection) validate their configuration and return a descriptive
//! [`engine::ScenarioError`] on degenerate inputs (zero
//! horizon/interval/rate/…) instead of panicking.

pub mod admission;
pub mod autoscale_sim;
pub mod decode_sim;
pub mod engine;
pub mod faults;
pub mod sweep;
pub mod tracegen;

pub use admission::{AdmissionConfig, AdmissionPolicy, PolicyKind};
pub use faults::{
    DegradationPolicy, FaultController, FaultEvent, FaultKind, FaultPlan, FaultStats,
    RecoveryAction, RetryConfig, ScriptedFault, StochasticFaults,
};
pub use autoscale_sim::{AutoscaleResult, AutoscaleSim};
pub use decode_sim::{evaluate_fixed_batch, FixedBatchResult};
pub use engine::{
    AutoscaleScenario, BinaryHeapEventQueue, EventKind, EventQueue, FailurePlan, FailureResult,
    FailureScenario, FixedBatchScenario, IntervalRecord, Scenario, ScenarioError, ScenarioOutcome,
    DEFAULT_QUEUE_CAPACITY,
};
pub use sweep::{
    hardware_threads, resolve_chunk, resolve_threads, run_cells, run_cells_filtered,
    run_cells_traced, CellResult, SweepCell,
};
pub use tracegen::{sample_bundle, sample_cells, TraceBundle};
