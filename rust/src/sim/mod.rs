//! Discrete-event evaluation harness.
//!
//! - [`decode_sim`] — fixed-batch decode-loop evaluation (drives Figs
//!   8/9/10/12): many decode steps with per-step routing draws, yielding
//!   TPOT distributions (mean + P99) and per-GPU throughput.
//! - [`autoscale_sim`] — trace-driven scaling over a diurnal trace with a
//!   periodic decision interval (drives Fig 11), mirroring the paper's
//!   trace-driven simulation methodology ("continuously running all
//!   systems over the full trace would require substantial cluster
//!   time" — §5.2).

pub mod autoscale_sim;
pub mod decode_sim;

pub use autoscale_sim::{AutoscaleResult, AutoscaleSim};
pub use decode_sim::{evaluate_fixed_batch, FixedBatchResult};
