//! Deterministic parallel sweep engine for independent simulation cells.
//!
//! The paper's evaluation — and every figure/golden/bench grid in this
//! repo — is an embarrassingly parallel sweep over independent
//! configurations: (system constructor × scenario config × seed) cells
//! that share nothing mutable. This module drains such a cell list with
//! `std::thread::scope` workers (no crates.io access, so no rayon —
//! hand-rolled work claiming over one atomic index) while keeping the
//! repo's bit-identical same-seed contract:
//!
//! **Worker count is not an observable.** Each cell's result is written
//! into a pre-sized slot at the cell's submission index, every cell owns
//! its RNG streams (derive them with [`crate::util::rng::split_seed`],
//! never by sharing a generator across cells), and no cell reads another
//! cell's output. Therefore `sweep(cells, t, f)` returns the same
//! `Vec<T>` — bit for bit — for any `t ≥ 1`, including `t = 1`, which
//! simply runs the cells in submission order on the calling thread.
//! `tests/sweep_determinism.rs` pins this.
//!
//! Thread-count resolution (CLI `--threads N` beats the `JANUS_THREADS`
//! environment variable beats the hardware parallelism) lives in
//! [`resolve_threads`] so every binary exposes the same knobs.
//!
//! Work claiming is **chunked**: each `fetch_add` claims K consecutive
//! cells (K auto-sized from the grid — about four claims per worker —
//! overridable via `JANUS_CHUNK` or [`sweep_chunked`]), so tiny-cell
//! grids stop contending on the shared atomic. Chunking changes only
//! which worker computes a cell, never which slot its result lands in:
//! output stays byte-identical for every K ≥ 1 and every thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::baselines::system::ServingSystem;
use crate::obs::{ObsMode, Recorder};
use crate::sim::engine::{self, Scenario, ScenarioError, ScenarioOutcome};

/// Environment variable consulted when no explicit `--threads` is given.
pub const THREADS_ENV: &str = "JANUS_THREADS";

/// Environment variable overriding the work-claim chunk size (cells
/// claimed per `fetch_add`).
pub const CHUNK_ENV: &str = "JANUS_CHUNK";

/// Number of hardware threads (1 when the query fails).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve the worker count for a sweep: an explicit request (CLI
/// `--threads`) wins, then the `JANUS_THREADS` environment variable,
/// then the hardware parallelism. Zero/unparsable values fall through to
/// the next source; the result is always ≥ 1.
pub fn resolve_threads(explicit: Option<usize>) -> usize {
    explicit
        .filter(|&n| n > 0)
        .or_else(|| {
            std::env::var(THREADS_ENV)
                .ok()
                .and_then(|s| s.trim().parse().ok())
                .filter(|&n: &usize| n > 0)
        })
        .unwrap_or_else(hardware_threads)
}

/// Resolve the work-claim chunk size: an explicit request wins, then
/// the `JANUS_CHUNK` environment variable, then an auto-sizing from the
/// grid — about four claims per worker, so tiny-cell grids stop hammering
/// the shared atomic while load balance stays fine-grained enough that a
/// slow chunk cannot strand a worker. Always ≥ 1.
pub fn resolve_chunk(explicit: Option<usize>, cells: usize, workers: usize) -> usize {
    explicit
        .filter(|&k| k > 0)
        .or_else(|| {
            std::env::var(CHUNK_ENV)
                .ok()
                .and_then(|s| s.trim().parse().ok())
                .filter(|&k: &usize| k > 0)
        })
        .unwrap_or_else(|| (cells / (workers.max(1) * 4)).max(1))
}

/// Run `f(i, &cells[i])` for every cell and return the results in
/// submission order. `threads` workers claim cells from one atomic
/// index (first-free-worker order — scheduling never affects which slot
/// a result lands in, only which worker computes it), `resolve_chunk`
/// cells per claim. With `threads <= 1` the cells run serially on the
/// calling thread; the output is bit-identical either way provided `f`
/// is a pure function of `(i, cell)` — the cell-isolation contract this
/// module documents.
///
/// A panic inside any cell propagates to the caller once the scope
/// joins, like the serial loop would.
pub fn sweep<C, T, F>(cells: &[C], threads: usize, f: F) -> Vec<T>
where
    C: Sync,
    T: Send,
    F: Fn(usize, &C) -> T + Sync,
{
    let workers = threads.max(1).min(cells.len());
    let chunk = resolve_chunk(None, cells.len(), workers);
    sweep_chunked(cells, threads, chunk, f)
}

/// [`sweep`] with an explicit work-claim chunk size: each `fetch_add`
/// claims the next `chunk` consecutive cells. Chunking changes only how
/// cells are handed to workers — every cell still computes `f(i, cell)`
/// into its own submission-indexed slot, so the output is byte-identical
/// for any `chunk ≥ 1` (K = 1 is the classic one-cell claim; K ≥ grid
/// size degenerates to one worker draining everything).
pub fn sweep_chunked<C, T, F>(cells: &[C], threads: usize, chunk: usize, f: F) -> Vec<T>
where
    C: Sync,
    T: Send,
    F: Fn(usize, &C) -> T + Sync,
{
    let workers = threads.max(1).min(cells.len());
    if workers <= 1 {
        return cells.iter().enumerate().map(|(i, c)| f(i, c)).collect();
    }
    let chunk = chunk.max(1);
    // Slot-per-cell result buffer: submission index == output index.
    // Each slot's mutex is locked exactly once (claimed ranges are
    // disjoint across workers) — it exists to make the write safe, not
    // to serialize anything.
    let slots: Vec<Mutex<Option<T>>> = cells.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= cells.len() {
                    break;
                }
                for i in start..(start + chunk).min(cells.len()) {
                    let out = f(i, &cells[i]);
                    // tidy:allow(no-panic-in-lib): poisoned slot means a worker already panicked
                    *slots[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                // tidy:allow(no-panic-in-lib): propagates a worker panic after scope join
                .expect("sweep slot poisoned")
                // tidy:allow(no-panic-in-lib): the claim loop covered every index
                .expect("sweep cell completed without a result")
        })
        .collect()
}

/// One unit of isolation in a scenario sweep: a system constructor, the
/// scenario it runs, and the seed of the run. The constructor executes
/// inside whichever worker claims the cell; the built system never
/// crosses a thread boundary.
pub struct SweepCell<'a> {
    /// Human-readable cell label (carried through to the result row).
    pub label: String,
    /// Builds a fresh system for this cell. Must be deterministic: two
    /// invocations yield identically-behaving systems (fixed ctor seed).
    pub build: Box<dyn Fn() -> Box<dyn ServingSystem> + Sync + 'a>,
    pub scenario: Scenario,
    pub seed: u64,
}

impl std::fmt::Debug for SweepCell<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SweepCell")
            .field("label", &self.label)
            .field("seed", &self.seed)
            .finish_non_exhaustive()
    }
}

/// Outcome of one [`SweepCell`], tagged with its label.
#[derive(Debug)]
pub struct CellResult {
    pub label: String,
    pub outcome: Result<ScenarioOutcome, ScenarioError>,
}

/// Drain a scenario-cell work queue over `threads` workers; results come
/// back in submission order regardless of worker count.
pub fn run_cells(cells: &[SweepCell<'_>], threads: usize) -> Vec<CellResult> {
    run_cells_filtered(cells, threads, None)
}

/// [`run_cells`] restricted to cells whose label contains `filter`
/// (substring match; `None` runs everything) — partial panel
/// regeneration for `bin/figures --cells`. Because every cell is a pure
/// function of (index, cell), a filtered run's rows are byte-identical
/// to the corresponding rows of a full run, in the full run's relative
/// order.
pub fn run_cells_filtered(
    cells: &[SweepCell<'_>],
    threads: usize,
    filter: Option<&str>,
) -> Vec<CellResult> {
    let selected: Vec<usize> = cells
        .iter()
        .enumerate()
        .filter(|(_, c)| filter.map_or(true, |needle| c.label.contains(needle)))
        .map(|(i, _)| i)
        .collect();
    sweep(&selected, threads, |_, &i| {
        let cell = &cells[i];
        let mut sys = (cell.build)();
        CellResult {
            label: cell.label.clone(),
            outcome: engine::run(sys.as_mut(), &cell.scenario, cell.seed),
        }
    })
}

/// [`run_cells`] with the telemetry plane live: every cell records into
/// its own [`Recorder`] at `mode` (tagged with the cell's submission
/// index as the trace `pid`), and the per-cell recorders are merged in
/// submission order after the sweep joins. Both the result rows and the
/// merged recorder — counters, phase ledger, and full-mode event bytes —
/// are therefore independent of the worker count, exactly like
/// [`run_cells`] itself. `mode` is always passed explicitly; consulting
/// `JANUS_OBS` is the caller's decision, never this function's.
pub fn run_cells_traced(
    cells: &[SweepCell<'_>],
    threads: usize,
    mode: ObsMode,
) -> (Vec<CellResult>, Recorder) {
    let pairs = sweep(cells, threads, |i, cell| {
        let mut sys = (cell.build)();
        let mut rec = Recorder::new(mode);
        rec.set_pid(i as u32);
        let outcome = engine::run_with_recorder(sys.as_mut(), &cell.scenario, cell.seed, &mut rec);
        (
            CellResult {
                label: cell.label.clone(),
                outcome,
            },
            rec,
        )
    });
    let mut merged = Recorder::new(mode);
    let mut results = Vec::with_capacity(pairs.len());
    for (res, rec) in pairs {
        merged.merge(&rec);
        results.push(res);
    }
    (results, merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::serving::Slo;
    use crate::sim::engine::FixedBatchScenario;
    use crate::util::rng::{split_seed, Rng};
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_land_in_submission_order_for_any_thread_count() {
        let cells: Vec<u64> = (0..37).collect();
        let expect: Vec<u64> = cells.iter().map(|&c| c * c + 1).collect();
        for threads in [1usize, 2, 3, 4, 8, 64] {
            let got = sweep(&cells, threads, |i, &c| {
                assert_eq!(cells[i], c, "index/cell mismatch");
                c * c + 1
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_sweeps() {
        let none: Vec<u32> = Vec::new();
        assert!(sweep(&none, 8, |_, &c| c).is_empty());
        assert_eq!(sweep(&[7u32], 8, |_, &c| c + 1), vec![8]);
    }

    #[test]
    fn per_cell_rng_streams_do_not_depend_on_scheduling() {
        // Cells draw from RNGs derived via split_seed(stream, index):
        // the draw sequence is a pure function of the cell, so any
        // worker count (and any claim interleaving) produces identical
        // outputs, and a cell run alone reproduces its in-sweep value.
        let cells: Vec<u64> = (0..16).collect();
        let draw = |_, &c: &u64| {
            let mut rng = Rng::seed_from_u64(split_seed(0xF1C5, c));
            (0..64).fold(0u64, |acc, _| acc.wrapping_add(rng.next_u64()))
        };
        let serial = sweep(&cells, 1, draw);
        let parallel = sweep(&cells, 4, draw);
        assert_eq!(serial, parallel);
        for k in [0usize, 7, 15] {
            let solo = sweep(&cells[k..=k], 1, draw);
            assert_eq!(solo[0], serial[k], "cell {k} not isolated");
        }
    }

    #[test]
    fn every_cell_runs_exactly_once() {
        static RUNS: AtomicUsize = AtomicUsize::new(0);
        RUNS.store(0, Ordering::SeqCst);
        let cells: Vec<usize> = (0..100).collect();
        let got = sweep(&cells, 8, |i, _| {
            RUNS.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(RUNS.load(Ordering::SeqCst), 100);
        assert_eq!(got, cells);
    }

    #[test]
    fn resolve_threads_precedence() {
        // Explicit beats everything; zero falls through.
        assert_eq!(resolve_threads(Some(3)), 3);
        assert!(resolve_threads(Some(0)) >= 1);
        assert!(resolve_threads(None) >= 1);
    }

    #[test]
    fn resolve_chunk_explicit_and_auto() {
        // Explicit beats everything (env-independent); the env/auto
        // fallback is only bounded here — an exact assert would break
        // under a set JANUS_CHUNK, the very knob this resolver adds
        // (tests share one process environment, like resolve_threads).
        assert_eq!(resolve_chunk(Some(5), 100, 4), 5);
        assert!(resolve_chunk(None, 128, 4) >= 1);
        assert!(resolve_chunk(None, 3, 8) >= 1);
        assert!(resolve_chunk(Some(0), 3, 8) >= 1, "zero falls through");
    }

    #[test]
    fn chunked_claims_keep_slot_per_cell_output_identical() {
        // Chunking changes only claim granularity: for K ∈ {1, 3, grid}
        // (and beyond) every thread count produces the serial output.
        let cells: Vec<u64> = (0..41).collect();
        let f = |i: usize, &c: &u64| -> u64 {
            let mut rng = Rng::seed_from_u64(split_seed(0xC4C4, c));
            rng.next_u64() ^ i as u64
        };
        let serial = sweep_chunked(&cells, 1, 1, f);
        for chunk in [1usize, 3, cells.len(), cells.len() * 2] {
            for threads in [2usize, 4, 8] {
                assert_eq!(
                    serial,
                    sweep_chunked(&cells, threads, chunk, f),
                    "chunk={chunk} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn filtered_cells_rows_are_byte_identical_to_full_run() {
        use crate::baselines::JanusSystem;
        use crate::config::hardware::paper_testbed;
        use crate::config::models::deepseek_v2;
        use crate::routing::gate::ExpertPopularity;

        let model = deepseek_v2();
        let hw = paper_testbed();
        let pop = ExpertPopularity::Uniform;
        let cells: Vec<SweepCell> = [16usize, 64, 128]
            .iter()
            .map(|&batch| SweepCell {
                label: format!("janus/B{batch}"),
                build: Box::new({
                    let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
                    move || {
                        Box::new(JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 42))
                            as Box<dyn ServingSystem>
                    }
                }),
                scenario: Scenario::FixedBatch(FixedBatchScenario {
                    batch,
                    slo: Slo::from_ms(200.0),
                    steps: 4,
                }),
                seed: 7,
            })
            .collect();
        let serialize = |rs: &[CellResult]| -> Vec<String> {
            rs.iter()
                .map(|r| match &r.outcome {
                    Ok(ScenarioOutcome::FixedBatch(f)) => format!(
                        "{}\t{:016x}\t{:016x}",
                        r.label,
                        f.tpot_mean.to_bits(),
                        f.tpot_p99.to_bits()
                    ),
                    other => panic!("unexpected outcome {other:?}"),
                })
                .collect()
        };
        let full = serialize(&run_cells(&cells, 2));
        // Substring filter picks a strict subset; its rows must be the
        // corresponding full-run rows, byte for byte.
        let filtered = serialize(&run_cells_filtered(&cells, 2, Some("B64")));
        assert_eq!(filtered, vec![full[1].clone()]);
        let two = serialize(&run_cells_filtered(&cells, 2, Some("B1")));
        assert_eq!(two, vec![full[0].clone(), full[2].clone()]);
        // No-match filter → empty; None → the full run.
        assert!(run_cells_filtered(&cells, 2, Some("nope")).is_empty());
        assert_eq!(serialize(&run_cells_filtered(&cells, 2, None)), full);
    }

    #[test]
    fn scenario_cells_run_and_keep_order() {
        use crate::baselines::JanusSystem;
        use crate::config::hardware::paper_testbed;
        use crate::config::models::deepseek_v2;
        use crate::routing::gate::ExpertPopularity;

        let model = deepseek_v2();
        let hw = paper_testbed();
        let pop = ExpertPopularity::Uniform;
        let cells: Vec<SweepCell> = [64usize, 128]
            .iter()
            .map(|&batch| SweepCell {
                label: format!("janus/B{batch}"),
                build: Box::new({
                    let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
                    move || {
                        Box::new(JanusSystem::build(
                            model.clone(),
                            hw.clone(),
                            &pop,
                            16,
                            42,
                        )) as Box<dyn ServingSystem>
                    }
                }),
                scenario: Scenario::FixedBatch(FixedBatchScenario {
                    batch,
                    slo: Slo::from_ms(200.0),
                    steps: 5,
                }),
                seed: 7,
            })
            .collect();
        let fingerprint = |rs: &[CellResult]| -> Vec<(String, u64)> {
            rs.iter()
                .map(|r| match &r.outcome {
                    Ok(ScenarioOutcome::FixedBatch(f)) => {
                        (r.label.clone(), f.tpot_mean.to_bits())
                    }
                    other => panic!("unexpected outcome {other:?}"),
                })
                .collect()
        };
        let serial = fingerprint(&run_cells(&cells, 1));
        let parallel = fingerprint(&run_cells(&cells, 2));
        assert_eq!(serial, parallel);
        assert_eq!(serial[0].0, "janus/B64");
        assert_eq!(serial[1].0, "janus/B128");
    }
}
