//! Deterministic parallel sweep engine for independent simulation cells.
//!
//! The paper's evaluation — and every figure/golden/bench grid in this
//! repo — is an embarrassingly parallel sweep over independent
//! configurations: (system constructor × scenario config × seed) cells
//! that share nothing mutable. This module drains such a cell list with
//! `std::thread::scope` workers (no crates.io access, so no rayon —
//! hand-rolled work claiming over one atomic index) while keeping the
//! repo's bit-identical same-seed contract:
//!
//! **Worker count is not an observable.** Each cell's result is written
//! into a pre-sized slot at the cell's submission index, every cell owns
//! its RNG streams (derive them with [`crate::util::rng::split_seed`],
//! never by sharing a generator across cells), and no cell reads another
//! cell's output. Therefore `sweep(cells, t, f)` returns the same
//! `Vec<T>` — bit for bit — for any `t ≥ 1`, including `t = 1`, which
//! simply runs the cells in submission order on the calling thread.
//! `tests/sweep_determinism.rs` pins this.
//!
//! Thread-count resolution (CLI `--threads N` beats the `JANUS_THREADS`
//! environment variable beats the hardware parallelism) lives in
//! [`resolve_threads`] so every binary exposes the same knobs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::baselines::system::ServingSystem;
use crate::sim::engine::{self, Scenario, ScenarioError, ScenarioOutcome};

/// Environment variable consulted when no explicit `--threads` is given.
pub const THREADS_ENV: &str = "JANUS_THREADS";

/// Number of hardware threads (1 when the query fails).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve the worker count for a sweep: an explicit request (CLI
/// `--threads`) wins, then the `JANUS_THREADS` environment variable,
/// then the hardware parallelism. Zero/unparsable values fall through to
/// the next source; the result is always ≥ 1.
pub fn resolve_threads(explicit: Option<usize>) -> usize {
    explicit
        .filter(|&n| n > 0)
        .or_else(|| {
            std::env::var(THREADS_ENV)
                .ok()
                .and_then(|s| s.trim().parse().ok())
                .filter(|&n: &usize| n > 0)
        })
        .unwrap_or_else(hardware_threads)
}

/// Run `f(i, &cells[i])` for every cell and return the results in
/// submission order. `threads` workers claim cells from one atomic
/// index (first-free-worker order — scheduling never affects which slot
/// a result lands in, only which worker computes it). With `threads <= 1`
/// the cells run serially on the calling thread; the output is
/// bit-identical either way provided `f` is a pure function of
/// `(i, cell)` — the cell-isolation contract this module documents.
///
/// A panic inside any cell propagates to the caller once the scope
/// joins, like the serial loop would.
pub fn sweep<C, T, F>(cells: &[C], threads: usize, f: F) -> Vec<T>
where
    C: Sync,
    T: Send,
    F: Fn(usize, &C) -> T + Sync,
{
    let workers = threads.max(1).min(cells.len());
    if workers <= 1 {
        return cells.iter().enumerate().map(|(i, c)| f(i, c)).collect();
    }
    // Slot-per-cell result buffer: submission index == output index.
    // Each slot's mutex is locked exactly once (cells are claimed via
    // fetch_add, so indices are disjoint across workers) — it exists to
    // make the write safe, not to serialize anything.
    let slots: Vec<Mutex<Option<T>>> = cells.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let out = f(i, &cells[i]);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep slot poisoned")
                .expect("sweep cell completed without a result")
        })
        .collect()
}

/// One unit of isolation in a scenario sweep: a system constructor, the
/// scenario it runs, and the seed of the run. The constructor executes
/// inside whichever worker claims the cell; the built system never
/// crosses a thread boundary.
pub struct SweepCell<'a> {
    /// Human-readable cell label (carried through to the result row).
    pub label: String,
    /// Builds a fresh system for this cell. Must be deterministic: two
    /// invocations yield identically-behaving systems (fixed ctor seed).
    pub build: Box<dyn Fn() -> Box<dyn ServingSystem> + Sync + 'a>,
    pub scenario: Scenario,
    pub seed: u64,
}

/// Outcome of one [`SweepCell`], tagged with its label.
pub struct CellResult {
    pub label: String,
    pub outcome: Result<ScenarioOutcome, ScenarioError>,
}

/// Drain a scenario-cell work queue over `threads` workers; results come
/// back in submission order regardless of worker count.
pub fn run_cells(cells: &[SweepCell<'_>], threads: usize) -> Vec<CellResult> {
    sweep(cells, threads, |_, cell| {
        let mut sys = (cell.build)();
        CellResult {
            label: cell.label.clone(),
            outcome: engine::run(sys.as_mut(), &cell.scenario, cell.seed),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::serving::Slo;
    use crate::sim::engine::FixedBatchScenario;
    use crate::util::rng::{split_seed, Rng};
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_land_in_submission_order_for_any_thread_count() {
        let cells: Vec<u64> = (0..37).collect();
        let expect: Vec<u64> = cells.iter().map(|&c| c * c + 1).collect();
        for threads in [1usize, 2, 3, 4, 8, 64] {
            let got = sweep(&cells, threads, |i, &c| {
                assert_eq!(cells[i], c, "index/cell mismatch");
                c * c + 1
            });
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_sweeps() {
        let none: Vec<u32> = Vec::new();
        assert!(sweep(&none, 8, |_, &c| c).is_empty());
        assert_eq!(sweep(&[7u32], 8, |_, &c| c + 1), vec![8]);
    }

    #[test]
    fn per_cell_rng_streams_do_not_depend_on_scheduling() {
        // Cells draw from RNGs derived via split_seed(stream, index):
        // the draw sequence is a pure function of the cell, so any
        // worker count (and any claim interleaving) produces identical
        // outputs, and a cell run alone reproduces its in-sweep value.
        let cells: Vec<u64> = (0..16).collect();
        let draw = |_, &c: &u64| {
            let mut rng = Rng::seed_from_u64(split_seed(0xF1C5, c));
            (0..64).fold(0u64, |acc, _| acc.wrapping_add(rng.next_u64()))
        };
        let serial = sweep(&cells, 1, draw);
        let parallel = sweep(&cells, 4, draw);
        assert_eq!(serial, parallel);
        for k in [0usize, 7, 15] {
            let solo = sweep(&cells[k..=k], 1, draw);
            assert_eq!(solo[0], serial[k], "cell {k} not isolated");
        }
    }

    #[test]
    fn every_cell_runs_exactly_once() {
        static RUNS: AtomicUsize = AtomicUsize::new(0);
        RUNS.store(0, Ordering::SeqCst);
        let cells: Vec<usize> = (0..100).collect();
        let got = sweep(&cells, 8, |i, _| {
            RUNS.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(RUNS.load(Ordering::SeqCst), 100);
        assert_eq!(got, cells);
    }

    #[test]
    fn resolve_threads_precedence() {
        // Explicit beats everything; zero falls through.
        assert_eq!(resolve_threads(Some(3)), 3);
        assert!(resolve_threads(Some(0)) >= 1);
        assert!(resolve_threads(None) >= 1);
    }

    #[test]
    fn scenario_cells_run_and_keep_order() {
        use crate::baselines::JanusSystem;
        use crate::config::hardware::paper_testbed;
        use crate::config::models::deepseek_v2;
        use crate::routing::gate::ExpertPopularity;

        let model = deepseek_v2();
        let hw = paper_testbed();
        let pop = ExpertPopularity::Uniform;
        let cells: Vec<SweepCell> = [64usize, 128]
            .iter()
            .map(|&batch| SweepCell {
                label: format!("janus/B{batch}"),
                build: Box::new({
                    let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
                    move || {
                        Box::new(JanusSystem::build(
                            model.clone(),
                            hw.clone(),
                            &pop,
                            16,
                            42,
                        )) as Box<dyn ServingSystem>
                    }
                }),
                scenario: Scenario::FixedBatch(FixedBatchScenario {
                    batch,
                    slo: Slo::from_ms(200.0),
                    steps: 5,
                }),
                seed: 7,
            })
            .collect();
        let fingerprint = |rs: &[CellResult]| -> Vec<(String, u64)> {
            rs.iter()
                .map(|r| match &r.outcome {
                    Ok(ScenarioOutcome::FixedBatch(f)) => {
                        (r.label.clone(), f.tpot_mean.to_bits())
                    }
                    other => panic!("unexpected outcome {other:?}"),
                })
                .collect()
        };
        let serial = fingerprint(&run_cells(&cells, 1));
        let parallel = fingerprint(&run_cells(&cells, 2));
        assert_eq!(serial, parallel);
        assert_eq!(serial[0].0, "janus/B64");
        assert_eq!(serial[1].0, "janus/B128");
    }
}
