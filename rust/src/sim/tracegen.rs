//! Canonical pinned trace bundle for the observability plane.
//!
//! One fixed cell grid — every mode, policy, and seed pinned explicitly,
//! nothing resolved from the environment — run through
//! [`crate::sim::sweep::run_cells_traced`] and serialized with the
//! [`crate::obs::export`] writers. Because the cells, the engine, and
//! the exporters are all deterministic, the bundle's bytes are a pure
//! function of `(mode,)`: identical across reruns, thread counts, and
//! CI env legs. `bin/trace` writes it to disk, `bin/figures
//! --trace-out` attaches it to a figures run, and
//! `tests/sweep_determinism.rs` pins the byte-identity claim.

use crate::baselines::{build_eval_system, EVAL_SYSTEMS};
use crate::config::hardware::paper_testbed;
use crate::config::models;
use crate::config::serving::Slo;
use crate::obs::export::{chrome_trace, metrics_tsv};
use crate::obs::{ObsMode, Recorder};
use crate::routing::gate::ExpertPopularity;
use crate::scaling::ScalingMode;
use crate::sim::admission::AdmissionConfig;
use crate::sim::engine::{AutoscaleScenario, FailureScenario, FixedBatchScenario, Scenario};
use crate::sim::faults::{DegradationPolicy, FaultPlan};
use crate::sim::sweep::{run_cells_traced, CellResult, SweepCell};
use crate::workload::trace::DiurnalTrace;

/// Seed of every sample cell (the goldens' canonical seed).
pub const SAMPLE_SEED: u64 = 424242;

/// A serialized telemetry bundle: the Chrome-trace JSON and the
/// counters/ledger TSV, plus the cell results the run produced.
#[derive(Debug)]
pub struct TraceBundle {
    /// Chrome-trace-event JSON (open with Perfetto / `chrome://tracing`).
    /// In `counters` mode the event stream is empty but still valid JSON.
    pub trace_json: String,
    /// Counters, phase lanes, and ledger summary as TSV.
    pub metrics_tsv: String,
    /// Per-cell scenario results, in submission order.
    pub results: Vec<CellResult>,
}

/// The pinned sample grid: one fixed-batch cell per evaluation system,
/// an autoscale ramp on Janus under both scaling modes (reactive and
/// closed-loop — the latter exercises the signal-snapshot instants),
/// and a failure-injection cell with the golden fault plan (crash,
/// straggler, transient comm, attention-host loss) under the replica
/// degradation policy. Every knob is pinned explicitly so the grid is
/// immune to `JANUS_ADMISSION` / `JANUS_SCALING` / `JANUS_FAULTS`.
pub fn sample_cells() -> Vec<SweepCell<'static>> {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 0.4 };
    let slo = Slo::from_ms(200.0);
    let mut cells: Vec<SweepCell<'static>> = Vec::new();
    for which in 0..EVAL_SYSTEMS {
        let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
        cells.push(SweepCell {
            label: format!("fixed/{which}/B64"),
            build: Box::new(move || build_eval_system(which, model.clone(), hw.clone(), &pop)),
            scenario: Scenario::FixedBatch(FixedBatchScenario {
                batch: 64,
                slo,
                steps: 40,
            }),
            seed: SAMPLE_SEED,
        });
    }
    for (name, mode) in [
        ("reactive", ScalingMode::Reactive),
        ("closed", ScalingMode::Closed),
    ] {
        let trace = DiurnalTrace::ramp(720.0 / 3600.0, 30.0, 1.0, 8.0, 4242);
        let mut scenario = AutoscaleScenario::new(300.0, 64.0, slo, trace);
        scenario.admission = AdmissionConfig::fifo();
        scenario.scaling = mode;
        let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
        cells.push(SweepCell {
            label: format!("autoscale/janus/{name}"),
            build: Box::new(move || build_eval_system(0, model.clone(), hw.clone(), &pop)),
            scenario: Scenario::Autoscale(scenario),
            seed: SAMPLE_SEED,
        });
    }
    {
        let plan = FaultPlan::new()
            .with_instance_crash(30.0, 60.0, 0)
            .with_straggler(50.0, 40.0, 2.0)
            .with_transient_comm(100.0, 20.0, 0.5)
            .with_attention_host_loss(140.0, 20.0, 1, false)
            .with_policy(DegradationPolicy::Replica);
        let mut scenario = FailureScenario::new(slo, 4.0, 32.0, 180.0).with_faults(plan);
        scenario.admission = AdmissionConfig::fifo();
        scenario.scaling = ScalingMode::Reactive;
        let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
        cells.push(SweepCell {
            label: "faults/janus/replica".to_string(),
            build: Box::new(move || build_eval_system(0, model.clone(), hw.clone(), &pop)),
            scenario: Scenario::FailureInjection(scenario),
            seed: SAMPLE_SEED,
        });
    }
    cells
}

/// Run the pinned sample grid at `mode` over `threads` workers and
/// serialize the merged recorder. The bundle's bytes depend only on
/// `mode` — never on `threads`, rerun count, or the environment.
pub fn sample_bundle(mode: ObsMode, threads: usize) -> TraceBundle {
    let cells = sample_cells();
    let (results, rec) = run_cells_traced(&cells, threads, mode);
    bundle_from(&rec, results)
}

/// Serialize an already-merged recorder into a [`TraceBundle`].
pub fn bundle_from(rec: &Recorder, results: Vec<CellResult>) -> TraceBundle {
    TraceBundle {
        trace_json: chrome_trace(rec.events()),
        metrics_tsv: metrics_tsv(rec),
        results,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Counter;

    #[test]
    fn sample_bundle_is_rerun_and_thread_invariant() {
        let a = sample_bundle(ObsMode::Full, 1);
        let b = sample_bundle(ObsMode::Full, 2);
        assert_eq!(a.trace_json, b.trace_json, "thread count leaked into trace bytes");
        assert_eq!(a.metrics_tsv, b.metrics_tsv, "thread count leaked into metrics bytes");
        let c = sample_bundle(ObsMode::Full, 1);
        assert_eq!(a.trace_json, c.trace_json, "rerun changed trace bytes");
    }

    #[test]
    fn counters_mode_has_metrics_but_no_events() {
        let cells = sample_cells();
        let (results, rec) = run_cells_traced(&cells, 2, ObsMode::Counters);
        assert_eq!(results.len(), cells.len());
        assert!(rec.events().is_empty(), "counters mode must not buffer events");
        assert!(rec.counter(Counter::DecodeSteps) > 0);
        assert!(rec.counter(Counter::FaultsOpened) >= 4, "fault plan has 4 windows");
        assert!(rec.ledger().total() > 0.0);
    }

    #[test]
    fn full_mode_trace_covers_every_track() {
        let bundle = sample_bundle(ObsMode::Full, 2);
        for needle in [
            "\"decode\"",
            "\"queue_wait\"",
            "\"decision\"",
            "\"signal\"",
            "\"recovery\"",
        ] {
            assert!(
                bundle.trace_json.contains(needle),
                "trace missing {needle}"
            );
        }
        for row in ["counter\tdecode_steps", "lane\tattention", "lane\tprefill"] {
            assert!(bundle.metrics_tsv.contains(row), "metrics missing {row}");
        }
    }
}
