//! A fully scripted [`ServingSystem`] for engine/admission tests and
//! benches: constant step time, explicit batch/KV capacities, scripted
//! per-decision feasibility. No RNG draws, no hidden state — perfect
//! for pinning admission-policy behavior without paying for a real
//! system build.

use crate::baselines::system::{ConfigInfo, ServingSystem, StepOutcome};
use crate::config::serving::Slo;
use crate::util::rng::Rng;

/// Deterministic mock: every knob the engine consults is a field.
#[derive(Debug)]
pub struct MockServingSystem {
    pub gpus: usize,
    /// Batch slots (`batch_capacity`).
    pub capacity: usize,
    /// Constant decode-step time, seconds.
    pub tpot: f64,
    /// KV token capacity (`kv_capacity_tokens`).
    pub kv_capacity: f64,
    /// Prefill cost per token, seconds (`prefill_cost` = tokens × this).
    pub prefill_per_token: f64,
    /// Scripted per-decision feasibility (true once exhausted).
    pub feasibility: Vec<bool>,
    /// Optional demand response: `(tokens_per_slot, max_capacity)`. When
    /// set, each `configure_for_demand(lambda, ..)` resizes `capacity`
    /// to `ceil(lambda / tokens_per_slot)` clamped to
    /// `[1, max_capacity]` — at an *unchanged* GPU count, so two runs
    /// that differ only in scaling policy accrue identical GPU-hours.
    demand_response: Option<(f64, usize)>,
    decisions: usize,
}

impl MockServingSystem {
    pub fn new(gpus: usize, capacity: usize, tpot: f64) -> Self {
        MockServingSystem {
            gpus,
            capacity,
            tpot,
            kv_capacity: capacity as f64 * 512.0,
            prefill_per_token: 5e-6,
            feasibility: Vec::new(),
            demand_response: None,
            decisions: 0,
        }
    }

    /// Builder-style KV capacity override (tokens).
    pub fn with_kv_capacity(mut self, tokens: f64) -> Self {
        self.kv_capacity = tokens;
        self
    }

    /// Builder-style prefill cost override (seconds per token).
    pub fn with_prefill_per_token(mut self, secs: f64) -> Self {
        self.prefill_per_token = secs;
        self
    }

    /// Enable the demand→capacity response: each decision provisions one
    /// batch slot per `tokens_per_slot` of demanded token rate, up to
    /// `max_capacity` slots, never below one. GPU count stays fixed.
    pub fn with_demand_response(mut self, tokens_per_slot: f64, max_capacity: usize) -> Self {
        self.demand_response = Some((tokens_per_slot, max_capacity));
        self
    }
}

impl ServingSystem for MockServingSystem {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn configure(&mut self, _batch: usize, slo: Slo) -> Option<ConfigInfo> {
        self.configure_for_demand(1.0, slo)
    }

    fn configure_for_demand(&mut self, lambda: f64, _slo: Slo) -> Option<ConfigInfo> {
        if let Some((tokens_per_slot, max_capacity)) = self.demand_response {
            let want = (lambda / tokens_per_slot).ceil() as usize;
            self.capacity = want.clamp(1, max_capacity);
        }
        let ok = self.feasibility.get(self.decisions).copied().unwrap_or(true);
        self.decisions += 1;
        ok.then(|| ConfigInfo {
            label: "mock".into(),
            gpus: self.gpus,
        })
    }

    fn step(&mut self, _batch: usize, _rng: &mut Rng) -> StepOutcome {
        StepOutcome {
            tpot: self.tpot,
            a_max: 1,
        }
    }

    fn gpus(&self) -> usize {
        self.gpus
    }

    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn kv_capacity_tokens(&self) -> f64 {
        self.kv_capacity
    }

    fn prefill_cost(&mut self, tokens: u32) -> f64 {
        tokens as f64 * self.prefill_per_token
    }

    fn label(&self) -> String {
        "mock".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_feasibility_then_default_true() {
        let mut m = MockServingSystem::new(4, 8, 0.05);
        m.feasibility = vec![true, false];
        let slo = Slo::from_ms(200.0);
        assert!(m.configure_for_demand(1.0, slo).is_some());
        assert!(m.configure_for_demand(1.0, slo).is_none());
        assert!(m.configure_for_demand(1.0, slo).is_some());
    }

    #[test]
    fn capacities_and_costs_are_the_fields() {
        let mut m = MockServingSystem::new(2, 4, 0.1)
            .with_kv_capacity(100.0)
            .with_prefill_per_token(1e-3);
        assert_eq!(m.batch_capacity(), 4);
        assert_eq!(m.kv_capacity_tokens(), 100.0);
        assert_eq!(m.prefill_cost(0), 0.0);
        assert!((m.prefill_cost(50) - 0.05).abs() < 1e-12);
        let mut rng = Rng::seed_from_u64(1);
        assert_eq!(m.step(4, &mut rng).tpot, 0.1);
    }

    #[test]
    fn demand_response_resizes_capacity_at_fixed_gpus() {
        let mut m = MockServingSystem::new(4, 8, 0.05).with_demand_response(20.0, 64);
        let slo = Slo::from_ms(200.0);
        assert!(m.configure_for_demand(163.0, slo).is_some());
        assert_eq!(m.batch_capacity(), 9); // ceil(163/20)
        assert_eq!(m.gpus(), 4);
        assert!(m.configure_for_demand(0.0, slo).is_some());
        assert_eq!(m.batch_capacity(), 1); // clamped up from zero
        assert!(m.configure_for_demand(1e9, slo).is_some());
        assert_eq!(m.batch_capacity(), 64); // clamped to max
        assert_eq!(m.gpus(), 4); // GPU count never moves
    }
}
