//! A fully scripted [`ServingSystem`] for engine/admission tests and
//! benches: constant step time, explicit batch/KV capacities, scripted
//! per-decision feasibility. No RNG draws, no hidden state — perfect
//! for pinning admission-policy behavior without paying for a real
//! system build.

use crate::baselines::system::{ConfigInfo, ServingSystem, StepOutcome};
use crate::config::serving::Slo;
use crate::sim::faults::{DegradationPolicy, RecoveryAction};
use crate::util::rng::Rng;

/// Deterministic mock: every knob the engine consults is a field.
#[derive(Debug)]
pub struct MockServingSystem {
    pub gpus: usize,
    /// Batch slots (`batch_capacity`).
    pub capacity: usize,
    /// Constant decode-step time, seconds.
    pub tpot: f64,
    /// KV token capacity (`kv_capacity_tokens`).
    pub kv_capacity: f64,
    /// Prefill cost per token, seconds (`prefill_cost` = tokens × this).
    pub prefill_per_token: f64,
    /// Scripted per-decision feasibility (true once exhausted).
    pub feasibility: Vec<bool>,
    /// Current straggler slowdown the fault plane last set (1.0 = none).
    pub straggler: f64,
    /// When set, `crash_instance` reports this scripted narrowed
    /// recovery `(moved_experts, transfer_secs)` instead of the default
    /// whole-pool path — lets engine tests pin the narrowed accounting
    /// without building a real placement.
    pub narrowed_crash: Option<(usize, f64)>,
    /// Experts the scripted narrowed crash *drops* (loses every replica
    /// of). Nonzero makes the scripted recovery infeasible — the engine
    /// then charges the full fault duration as MTTR, mimicking a static
    /// placement whose saturated instances cannot re-seat anything.
    pub crash_dropped: usize,
    /// When set alongside [`narrowed_crash`](Self::narrowed_crash), the
    /// scripted crash recovery declares service restored this many
    /// seconds after the crash — mimicking an availability-aware
    /// placement that re-seats every lost expert and closes the
    /// degraded window early.
    pub restored_secs: Option<f64>,
    /// Instances `crash_instance` was called with, in order.
    pub crash_log: Vec<u32>,
    /// Instances `restore_instance` was called with, in order.
    pub restore_log: Vec<u32>,
    /// Optional demand response: `(tokens_per_slot, max_capacity)`. When
    /// set, each `configure_for_demand(lambda, ..)` resizes `capacity`
    /// to `ceil(lambda / tokens_per_slot)` clamped to
    /// `[1, max_capacity]` — at an *unchanged* GPU count, so two runs
    /// that differ only in scaling policy accrue identical GPU-hours.
    demand_response: Option<(f64, usize)>,
    decisions: usize,
}

impl MockServingSystem {
    pub fn new(gpus: usize, capacity: usize, tpot: f64) -> Self {
        MockServingSystem {
            gpus,
            capacity,
            tpot,
            kv_capacity: capacity as f64 * 512.0,
            prefill_per_token: 5e-6,
            feasibility: Vec::new(),
            straggler: 1.0,
            narrowed_crash: None,
            crash_dropped: 0,
            restored_secs: None,
            crash_log: Vec::new(),
            restore_log: Vec::new(),
            demand_response: None,
            decisions: 0,
        }
    }

    /// Builder-style scripted narrowed crash recovery: `crash_instance`
    /// returns `expert_replacement(moved, 0, transfer)` without touching
    /// capacity, mimicking a system that re-places only the dead
    /// instance's experts.
    pub fn with_narrowed_crash(mut self, moved: usize, transfer: f64) -> Self {
        self.narrowed_crash = Some((moved, transfer));
        self
    }

    /// Script `dropped` lost experts into the narrowed crash recovery
    /// (making it infeasible): a stand-in for a *static* placement with
    /// zero free slots, where a crash permanently drops every expert
    /// whose sole replica lived on the dead instance.
    pub fn with_crash_dropped(mut self, dropped: usize) -> Self {
        self.crash_dropped = dropped;
        self
    }

    /// Script an early service-restored declaration into the narrowed
    /// crash recovery: a stand-in for an *availability-aware* placement
    /// that re-seats every lost expert from surviving replicas and ends
    /// the degraded window `secs` after the crash instead of waiting out
    /// the full fault duration.
    pub fn with_restored_secs(mut self, secs: f64) -> Self {
        self.restored_secs = Some(secs);
        self
    }

    /// Builder-style KV capacity override (tokens).
    pub fn with_kv_capacity(mut self, tokens: f64) -> Self {
        self.kv_capacity = tokens;
        self
    }

    /// Builder-style prefill cost override (seconds per token).
    pub fn with_prefill_per_token(mut self, secs: f64) -> Self {
        self.prefill_per_token = secs;
        self
    }

    /// Enable the demand→capacity response: each decision provisions one
    /// batch slot per `tokens_per_slot` of demanded token rate, up to
    /// `max_capacity` slots, never below one. GPU count stays fixed.
    pub fn with_demand_response(mut self, tokens_per_slot: f64, max_capacity: usize) -> Self {
        self.demand_response = Some((tokens_per_slot, max_capacity));
        self
    }
}

impl ServingSystem for MockServingSystem {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn configure(&mut self, _batch: usize, slo: Slo) -> Option<ConfigInfo> {
        self.configure_for_demand(1.0, slo)
    }

    fn configure_for_demand(&mut self, lambda: f64, _slo: Slo) -> Option<ConfigInfo> {
        if let Some((tokens_per_slot, max_capacity)) = self.demand_response {
            let want = (lambda / tokens_per_slot).ceil() as usize;
            self.capacity = want.clamp(1, max_capacity);
        }
        let ok = self.feasibility.get(self.decisions).copied().unwrap_or(true);
        self.decisions += 1;
        ok.then(|| ConfigInfo {
            label: "mock".into(),
            gpus: self.gpus,
        })
    }

    fn step(&mut self, _batch: usize, _rng: &mut Rng) -> StepOutcome {
        StepOutcome {
            tpot: self.tpot,
            a_max: 1,
        }
    }

    fn gpus(&self) -> usize {
        self.gpus
    }

    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn kv_capacity_tokens(&self) -> f64 {
        self.kv_capacity
    }

    fn prefill_cost(&mut self, tokens: u32) -> f64 {
        tokens as f64 * self.prefill_per_token
    }

    fn label(&self) -> String {
        "mock".into()
    }

    fn crash_instance(
        &mut self,
        instance: u32,
        _policy: DegradationPolicy,
        lambda: f64,
        slo: Slo,
    ) -> RecoveryAction {
        self.crash_log.push(instance);
        match self.narrowed_crash {
            Some((moved, transfer)) => {
                let mut action =
                    RecoveryAction::expert_replacement(moved, self.crash_dropped, transfer);
                if let Some(secs) = self.restored_secs {
                    action = action.with_service_restored(secs);
                }
                action
            }
            None => {
                self.fail_gpus(1);
                RecoveryAction::whole_pool(self.reconfigure_for_pool(lambda, slo).is_some())
            }
        }
    }

    fn restore_instance(&mut self, instance: u32, lambda: f64, slo: Slo) -> RecoveryAction {
        self.restore_log.push(instance);
        match self.narrowed_crash {
            Some((moved, transfer)) => RecoveryAction::expert_replacement(moved, 0, transfer),
            None => {
                self.restore_gpus(1);
                RecoveryAction::whole_pool(self.reconfigure_for_pool(lambda, slo).is_some())
            }
        }
    }

    fn set_straggler(&mut self, factor: f64) {
        self.straggler = if factor.is_finite() { factor.max(1.0) } else { 1.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_feasibility_then_default_true() {
        let mut m = MockServingSystem::new(4, 8, 0.05);
        m.feasibility = vec![true, false];
        let slo = Slo::from_ms(200.0);
        assert!(m.configure_for_demand(1.0, slo).is_some());
        assert!(m.configure_for_demand(1.0, slo).is_none());
        assert!(m.configure_for_demand(1.0, slo).is_some());
    }

    #[test]
    fn capacities_and_costs_are_the_fields() {
        let mut m = MockServingSystem::new(2, 4, 0.1)
            .with_kv_capacity(100.0)
            .with_prefill_per_token(1e-3);
        assert_eq!(m.batch_capacity(), 4);
        assert_eq!(m.kv_capacity_tokens(), 100.0);
        assert_eq!(m.prefill_cost(0), 0.0);
        assert!((m.prefill_cost(50) - 0.05).abs() < 1e-12);
        let mut rng = Rng::seed_from_u64(1);
        assert_eq!(m.step(4, &mut rng).tpot, 0.1);
    }

    #[test]
    fn demand_response_resizes_capacity_at_fixed_gpus() {
        let mut m = MockServingSystem::new(4, 8, 0.05).with_demand_response(20.0, 64);
        let slo = Slo::from_ms(200.0);
        assert!(m.configure_for_demand(163.0, slo).is_some());
        assert_eq!(m.batch_capacity(), 9); // ceil(163/20)
        assert_eq!(m.gpus(), 4);
        assert!(m.configure_for_demand(0.0, slo).is_some());
        assert_eq!(m.batch_capacity(), 1); // clamped up from zero
        assert!(m.configure_for_demand(1e9, slo).is_some());
        assert_eq!(m.batch_capacity(), 64); // clamped to max
        assert_eq!(m.gpus(), 4); // GPU count never moves
    }

    #[test]
    fn fault_hooks_log_and_script_narrowed_recovery() {
        let slo = Slo::from_ms(200.0);
        // Default path: whole-pool recovery, crash/restore logged.
        let mut m = MockServingSystem::new(4, 8, 0.05);
        let a = m.crash_instance(2, DegradationPolicy::Off, 10.0, slo);
        assert!(!a.narrowed);
        let b = m.restore_instance(2, 10.0, slo);
        assert!(!b.narrowed);
        assert_eq!(m.crash_log, vec![2]);
        assert_eq!(m.restore_log, vec![2]);

        // Scripted narrowed path: expert replacement, capacity untouched.
        let mut n = MockServingSystem::new(4, 8, 0.05).with_narrowed_crash(3, 0.25);
        let c = n.crash_instance(1, DegradationPolicy::Replica, 10.0, slo);
        assert!(c.narrowed);
        assert_eq!(c.moved_experts, 3);
        assert!((c.transfer_secs - 0.25).abs() < 1e-12);
        assert_eq!(n.batch_capacity(), 8);

        // Straggler factor is stored, clamped to >= 1, cleared at 1.0.
        n.set_straggler(2.5);
        assert_eq!(n.straggler, 2.5);
        n.set_straggler(0.3);
        assert_eq!(n.straggler, 1.0);
    }

    #[test]
    fn scripted_drops_and_restoration_shape_the_recovery() {
        let slo = Slo::from_ms(200.0);
        // Static stand-in: narrowed but dropping experts → infeasible,
        // and no early restoration is declared.
        let mut s = MockServingSystem::new(4, 8, 0.05)
            .with_narrowed_crash(0, 0.0)
            .with_crash_dropped(3);
        let a = s.crash_instance(0, DegradationPolicy::Replica, 10.0, slo);
        assert!(a.narrowed && !a.feasible);
        assert_eq!(a.dropped_experts, 3);
        assert_eq!(a.restored_secs, None);

        // Coact stand-in: every expert re-seated, service restored early.
        let mut c = MockServingSystem::new(4, 8, 0.05)
            .with_narrowed_crash(5, 0.4)
            .with_restored_secs(1.5);
        let b = c.crash_instance(0, DegradationPolicy::Replica, 10.0, slo);
        assert!(b.narrowed && b.feasible);
        assert_eq!(b.dropped_experts, 0);
        assert_eq!(b.restored_secs, Some(1.5));
        // Restore path stays on the plain scripted shape.
        let r = c.restore_instance(0, 10.0, slo);
        assert_eq!(r.restored_secs, None);
        assert_eq!(r.dropped_experts, 0);
    }
}
