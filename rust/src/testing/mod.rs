//! Test support. `proptest` is unavailable in this offline build
//! environment, so `prop` provides a small seeded property-test harness
//! with the same spirit: generate many random cases, assert an invariant,
//! and report the failing seed for reproduction.

pub mod prop;
