//! Test support. `proptest` is unavailable in this offline build
//! environment, so `prop` provides a small seeded property-test harness
//! with the same spirit: generate many random cases, assert an invariant,
//! and report the failing seed for reproduction. `mock_system` is a
//! fully scripted `ServingSystem` for engine/admission tests and benches.

pub mod mock_system;
pub mod prop;

pub use mock_system::MockServingSystem;
