//! Lightweight property-testing harness (offline substitute for proptest).
//!
//! Usage:
//! ```rust,no_run
//! use janus::testing::prop::check;
//! check("sum is commutative", 100, |rng| {
//!     let a = rng.usize_below(1000) as i64;
//!     let b = rng.usize_below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! On failure the panic message includes the per-case seed so the case can
//! be replayed with `check_one`.

use crate::util::rng::Rng;

/// Base seed; override with `JANUS_PROP_SEED` to replay a failure sweep.
fn base_seed() -> u64 {
    std::env::var("JANUS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x4A4E_5553) // "JNUS"
}

/// Run `cases` random cases of property `f`. Panics with the failing seed.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, f: F) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seed_from_u64(seed);
            f(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            // tidy:allow(no-panic-in-lib): test harness — failure reporting is its job
            panic!(
                "property '{name}' failed at case {i} (seed {seed:#x}): {msg}\n\
                 replay with janus::testing::prop::check_one({seed:#x}, ..)"
            );
        }
    }
}

/// Replay a single case by seed.
pub fn check_one<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::seed_from_u64(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("trivial", 50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_rng| {
                panic!("boom");
            });
        });
        let err = r.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "message was: {msg}");
        assert!(msg.contains("boom"), "message was: {msg}");
    }

    #[test]
    fn check_one_replays() {
        let mut seen = 0u64;
        check_one(42, |rng| seen = rng.next_u64());
        let mut again = 0u64;
        check_one(42, |rng| again = rng.next_u64());
        assert_eq!(seen, again);
    }
}
