//! Minimal benchmarking harness (criterion is unavailable in this
//! offline build environment — see DESIGN.md). Measures wall time over
//! repeated runs with warmup, reporting mean/median/min per iteration,
//! and serializes machine-readable `BENCH_*.json` trajectory files so
//! each PR's perf numbers accumulate as CI artifacts.

use std::io;
use std::path::Path;
use std::time::Instant;

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} us", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        };
        println!(
            "{:<52} {:>12}/iter  (median {:>12}, min {:>12}, {} iters)",
            self.name,
            fmt(self.mean_ns),
            fmt(self.median_ns),
            fmt(self.min_ns),
            self.iters
        );
    }
}

/// Benchmark `f`, auto-scaling the iteration count to ~`target_ms` of
/// total measurement time, in `samples` batches.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 300.0, 10, &mut f)
}

/// Configurable variant.
pub fn bench_cfg<F: FnMut()>(
    name: &str,
    target_ms: f64,
    samples: usize,
    f: &mut F,
) -> BenchResult {
    // Warmup + calibration: how many iters fit in one sample budget?
    let t0 = Instant::now();
    let mut calib_iters = 0u64;
    while t0.elapsed().as_secs_f64() < target_ms / 1e3 / samples as f64 {
        f();
        calib_iters += 1;
        if calib_iters >= 1_000_000 {
            break;
        }
    }
    let per_sample = calib_iters.max(1);
    let mut sample_ns: Vec<f64> = Vec::with_capacity(samples);
    let mut total_iters = 0u64;
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            f();
        }
        sample_ns.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
        total_iters += per_sample;
    }
    sample_ns.sort_by(f64::total_cmp);
    let mean = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        median_ns: sample_ns[sample_ns.len() / 2],
        min_ns: sample_ns[0],
    };
    result.report();
    result
}

/// One row of a `BENCH_*.json` trajectory: a bench's mean per-iteration
/// time, the equivalent rate (steps/s for decode-step benches), and —
/// for sweep benches — the worker count the measurement ran at.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    pub mean_ns: f64,
    pub steps_per_s: f64,
    /// Sweep worker count of the measurement; `None` for single-threaded
    /// micro-benches (serialized as absent).
    pub threads: Option<usize>,
    /// Admission policy of the measurement (`fifo` / `slo` / `kv`);
    /// `None` for benches that don't go through admission (absent in
    /// the JSON).
    pub policy: Option<String>,
    /// Observability mode of the measurement (`off` / `counters` /
    /// `full`); `None` for benches that don't drive a recorder (absent
    /// in the JSON).
    pub obs: Option<String>,
}

impl BenchRecord {
    /// Derive a record from a harness result (rate = 1e9 / mean ns).
    pub fn from_result(r: &BenchResult) -> Self {
        BenchRecord {
            name: r.name.clone(),
            mean_ns: r.mean_ns,
            steps_per_s: if r.mean_ns > 0.0 { 1e9 / r.mean_ns } else { 0.0 },
            threads: None,
            policy: None,
            obs: None,
        }
    }

    /// Tag the record with the sweep worker count it was measured at.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Tag the record with the admission policy it was measured under.
    pub fn with_policy(mut self, policy: &str) -> Self {
        self.policy = Some(policy.to_string());
        self
    }

    /// Tag the record with the observability mode it was measured under.
    pub fn with_obs(mut self, obs: &str) -> Self {
        self.obs = Some(obs.to_string());
        self
    }
}

/// Minimal JSON string escaping (bench names are plain ASCII, but don't
/// trust that).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a `BENCH_*.json` trajectory document (schema `janus-bench-v4`:
/// v3 plus an optional per-record `obs` field for recorder-overhead
/// benches). `timestamp_unix_s` and `hardware_threads` are passed in by
/// the caller (the bench binary) — the harness itself never reads a
/// clock for anything but interval measurement, and simulation code
/// never reads one at all. Non-finite values serialize as 0 to keep the
/// document valid JSON.
pub fn bench_json(
    timestamp_unix_s: u64,
    hardware_threads: usize,
    records: &[BenchRecord],
) -> String {
    let num = |x: f64| -> String {
        if x.is_finite() {
            format!("{x:.3}")
        } else {
            "0".to_string()
        }
    };
    let mut out = String::from("{\n");
    out.push_str("  \"schema\": \"janus-bench-v4\",\n");
    out.push_str(&format!("  \"generated_unix_s\": {timestamp_unix_s},\n"));
    out.push_str(&format!("  \"hardware_threads\": {hardware_threads},\n"));
    out.push_str("  \"benches\": [\n");
    for (i, r) in records.iter().enumerate() {
        let threads = r
            .threads
            .map(|t| format!(", \"threads\": {t}"))
            .unwrap_or_default();
        let policy = r
            .policy
            .as_ref()
            .map(|p| format!(", \"policy\": \"{}\"", json_escape(p)))
            .unwrap_or_default();
        let obs = r
            .obs
            .as_ref()
            .map(|o| format!(", \"obs\": \"{}\"", json_escape(o)))
            .unwrap_or_default();
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {}, \"steps_per_s\": {}{}{}{}}}{}\n",
            json_escape(&r.name),
            num(r.mean_ns),
            num(r.steps_per_s),
            threads,
            policy,
            obs,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the trajectory document to `path` (the benches put it at the
/// repo root as `BENCH_sim.json`; CI uploads it as an artifact).
pub fn write_bench_json(
    path: &Path,
    timestamp_unix_s: u64,
    hardware_threads: usize,
    records: &[BenchRecord],
) -> io::Result<()> {
    std::fs::write(
        path,
        bench_json(timestamp_unix_s, hardware_threads, records),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut x = 0u64;
        let r = bench_cfg("spin", 5.0, 3, &mut || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.min_ns <= r.median_ns);
    }

    #[test]
    fn json_document_shape() {
        let records = vec![
            BenchRecord {
                name: "janus/step B=256".to_string(),
                mean_ns: 12_345.678,
                steps_per_s: 81_000.5,
                threads: None,
                policy: None,
                obs: None,
            },
            BenchRecord {
                name: "sweep/figures-grid".to_string(),
                mean_ns: 1e6,
                steps_per_s: 1e3,
                threads: Some(4),
                policy: None,
                obs: None,
            },
            BenchRecord {
                name: "quote\"and\\slash".to_string(),
                mean_ns: f64::NAN,
                steps_per_s: f64::INFINITY,
                threads: None,
                policy: None,
                obs: None,
            },
            BenchRecord {
                name: "admission/decode-loop".to_string(),
                mean_ns: 2e3,
                steps_per_s: 5e5,
                threads: None,
                policy: Some("kv".to_string()),
                obs: None,
            },
            BenchRecord {
                name: "obs/step+record".to_string(),
                mean_ns: 4e3,
                steps_per_s: 2.5e5,
                threads: None,
                policy: None,
                obs: Some("counters".to_string()),
            },
        ];
        let doc = bench_json(1_753_000_000, 8, &records);
        assert!(doc.contains("\"schema\": \"janus-bench-v4\""));
        assert!(doc.contains("\"generated_unix_s\": 1753000000"));
        assert!(doc.contains("\"hardware_threads\": 8"));
        assert!(doc.contains("\"mean_ns\": 12345.678"));
        assert!(doc.contains("\"steps_per_s\": 81000.500"));
        // Sweep records carry their worker count; micro-benches don't.
        assert!(doc.contains("\"steps_per_s\": 1000.000, \"threads\": 4"));
        assert_eq!(doc.matches("\"threads\":").count(), 1);
        // Admission records carry their policy; everything else doesn't.
        assert!(doc.contains("\"steps_per_s\": 500000.000, \"policy\": \"kv\""));
        assert_eq!(doc.matches("\"policy\":").count(), 1);
        // Recorder-overhead records carry their obs mode; others don't.
        assert!(doc.contains("\"steps_per_s\": 250000.000, \"obs\": \"counters\""));
        assert_eq!(doc.matches("\"obs\":").count(), 1);
        // Escaping + non-finite fallback keep the document valid.
        assert!(doc.contains("quote\\\"and\\\\slash"));
        assert!(doc.contains("\"mean_ns\": 0, \"steps_per_s\": 0"));
        // Exactly one trailing-comma-free last element.
        assert!(!doc.contains(",\n  ]"));
    }

    #[test]
    fn record_from_result_inverts_rate() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 2e6,
            median_ns: 2e6,
            min_ns: 2e6,
        };
        let rec = BenchRecord::from_result(&r);
        assert!((rec.steps_per_s - 500.0).abs() < 1e-9);
        assert_eq!(rec.threads, None);
        assert_eq!(rec.with_threads(3).threads, Some(3));
    }
}
