//! Minimal benchmarking harness (criterion is unavailable in this
//! offline build environment — see DESIGN.md). Measures wall time over
//! repeated runs with warmup, reporting mean/median/min per iteration.

use std::time::Instant;

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        let fmt = |ns: f64| -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} us", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        };
        println!(
            "{:<52} {:>12}/iter  (median {:>12}, min {:>12}, {} iters)",
            self.name,
            fmt(self.mean_ns),
            fmt(self.median_ns),
            fmt(self.min_ns),
            self.iters
        );
    }
}

/// Benchmark `f`, auto-scaling the iteration count to ~`target_ms` of
/// total measurement time, in `samples` batches.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 300.0, 10, &mut f)
}

/// Configurable variant.
pub fn bench_cfg<F: FnMut()>(
    name: &str,
    target_ms: f64,
    samples: usize,
    f: &mut F,
) -> BenchResult {
    // Warmup + calibration: how many iters fit in one sample budget?
    let t0 = Instant::now();
    let mut calib_iters = 0u64;
    while t0.elapsed().as_secs_f64() < target_ms / 1e3 / samples as f64 {
        f();
        calib_iters += 1;
        if calib_iters >= 1_000_000 {
            break;
        }
    }
    let per_sample = calib_iters.max(1);
    let mut sample_ns: Vec<f64> = Vec::with_capacity(samples);
    let mut total_iters = 0u64;
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            f();
        }
        sample_ns.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
        total_iters += per_sample;
    }
    sample_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sample_ns.iter().sum::<f64>() / sample_ns.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: mean,
        median_ns: sample_ns[sample_ns.len() / 2],
        min_ns: sample_ns[0],
    };
    result.report();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut x = 0u64;
        let r = bench_cfg("spin", 5.0, 3, &mut || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.min_ns <= r.median_ns);
    }
}
