//! Minimal command-line parsing (no crates.io access for `clap` in this
//! build environment). Supports `--flag`, `--key value`, `--key=value`,
//! and positional arguments.

use std::collections::HashMap;

/// Parsed command line: positionals plus a key→value map (flags map to "true").
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
}

impl Args {
    /// Parse from the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (testable).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    // tidy:allow(no-panic-in-lib): peek() just proved a next element exists
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Optional integer option: `None` when absent, panics on garbage
    /// (matching the `_or` accessors' strictness).
    pub fn usize_opt(&self, name: &str) -> Option<usize> {
        self.get(name).map(|v| {
            v.parse()
                // tidy:allow(no-panic-in-lib): CLI arg errors abort by design
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v}"))
        })
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            // tidy:allow(no-panic-in-lib): CLI arg errors abort by design
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            // tidy:allow(no-panic-in-lib): CLI arg errors abort by design
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            // tidy:allow(no-panic-in-lib): CLI arg errors abort by design
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["fig8", "--slo", "200", "--model=dsv2", "--verbose"]);
        assert_eq!(a.positional, vec!["fig8"]);
        assert_eq!(a.get("slo"), Some("200"));
        assert_eq!(a.get("model"), Some("dsv2"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "12", "--rate", "3.5"]);
        assert_eq!(a.usize_or("n", 0), 12);
        assert_eq!(a.f64_or("rate", 0.0), 3.5);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.usize_opt("n"), Some(12));
        assert_eq!(a.usize_opt("missing"), None);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--a", "--b"]);
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }
}
