//! Shared utilities: deterministic RNG, statistics, CLI parsing, tables.

pub mod bench;
pub mod cli;
pub mod rng;
pub mod stats;
pub mod table;
