//! Deterministic pseudo-random number generation and sampling.
//!
//! The build environment has no network access to crates.io, so the usual
//! `rand` / `rand_distr` stack is unavailable. This module provides a
//! self-contained xoshiro256++ generator plus the distributions the Janus
//! reproduction needs (uniform, normal, exponential, gamma, Poisson, Zipf).
//! Everything is seeded and fully deterministic, which we rely on for
//! reproducible experiments and for the synchronization-free AEBS property
//! (identical inputs ⇒ identical decisions on every instance).

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64, used to expand a single seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive an independent seed for cell `index` of logical stream
/// `stream` (two splitmix64 rounds: the first avalanches the stream id,
/// the second avalanches the index on top of it). Sweep cells and
/// figure-panel reps seed their RNGs with this so a cell's draw sequence
/// is a pure function of `(stream, index)` — never of which cells ran
/// before it or on which worker thread it ran.
pub fn split_seed(stream: u64, index: u64) -> u64 {
    let mut s = stream;
    let mixed_stream = splitmix64(&mut s);
    let mut s2 = mixed_stream ^ index;
    splitmix64(&mut s2)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize_below(0)");
        // Lemire's multiply-shift rejection method for unbiased bounded ints.
        let n64 = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            let low = m as u64;
            if low >= n64 || low >= low.wrapping_neg() % n64 {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform u32 in [lo, hi] inclusive.
    pub fn u32_range(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + self.usize_below((hi - lo + 1) as usize) as u32
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability p.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via the Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64_open().ln() / lambda
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang; boosts k<1.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let u = self.f64_open();
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Poisson(lambda). Knuth for small lambda, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Gaussian approximation with continuity correction; adequate for
            // workload arrival synthesis at the rates we use.
            let x = self.normal_ms(lambda, lambda.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Log-normal with given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Sample an index from unnormalized weights (linear scan).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: all-zero weights");
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf sampler over {0, .., n-1} with exponent s ≥ 0
/// (s = 0 reduces to uniform). Inverse-CDF lookups are accelerated by a
/// guide table (first CDF index per uniform u-bucket), so a draw costs
/// ~1 probe instead of an O(log n) binary search — this sits on the
/// decode hot path via `GateSim::sample_token` (top_k draws per token
/// per step). The guided lookup returns exactly the index the binary
/// search would (first rank whose CDF reaches u), so draws stay
/// bit-identical.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
    /// `guide[b]` = first index j with `(cdf[j] * buckets) as usize >= b`
    /// — a draw whose u lands in bucket b starts its scan there.
    guide: Vec<u32>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        // ~4 buckets per rank keeps the expected scan length below one
        // extra probe even for a flat (s = 0) distribution. Each guide
        // entry is derived from the CDF values' OWN bucket indices —
        // computed with the exact float expression `index_of` applies to
        // u — so the skip is sound at 1-ulp bucket boundaries: x ↦
        // (x·buckets) as usize is monotone, hence bucket(cdf[j]) <
        // bucket(u) implies cdf[j] < u.
        let buckets = (4 * n).max(16);
        let mut guide = Vec::with_capacity(buckets);
        let mut j = 0usize;
        for b in 0..buckets {
            while j < cdf.len() && ((cdf[j] * buckets as f64) as usize) < b {
                j += 1;
            }
            guide.push(j as u32);
        }
        Zipf { cdf, guide }
    }

    /// Probability mass of rank i.
    pub fn pmf(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// First rank whose CDF reaches `u` (capped at n-1) — the same index
    /// `cdf.binary_search_by(partial_cmp(&u))` resolves to, found from
    /// the bucket's guide entry instead.
    fn index_of(&self, u: f64) -> usize {
        let buckets = self.guide.len();
        let bucket = ((u * buckets as f64) as usize).min(buckets - 1);
        // Every index before guide[bucket] has bucket(cdf) < bucket(u),
        // hence cdf < u (monotone bucket map — see the constructor).
        let mut j = self.guide[bucket] as usize;
        while j < self.cdf.len() && self.cdf[j] < u {
            j += 1;
        }
        j.min(self.cdf.len() - 1)
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        self.index_of(rng.f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_seed_is_deterministic_and_spreads() {
        assert_eq!(split_seed(1, 2), split_seed(1, 2));
        // No collisions over a figure-panel-sized grid, and no seed maps
        // to itself or to its raw inputs (the streams must be disjoint
        // from naive seed reuse).
        let mut seen = std::collections::HashSet::new();
        for stream in 0..64u64 {
            for index in 0..64u64 {
                let s = split_seed(stream, index);
                assert!(seen.insert(s), "collision at ({stream}, {index})");
                assert_ne!(s, stream);
                assert_ne!(s, index);
            }
        }
        // Adjacent indices yield uncorrelated generators.
        let mut a = Rng::seed_from_u64(split_seed(9, 0));
        let mut b = Rng::seed_from_u64(split_seed(9, 1));
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_below_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.usize_below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gamma_mean_var() {
        let mut r = Rng::seed_from_u64(17);
        let (k, theta) = (3.0, 2.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, theta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::seed_from_u64(19);
        let n = 50_000;
        let mean = (0..n).map(|_| r.gamma(0.5, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn poisson_small_and_large() {
        let mut r = Rng::seed_from_u64(23);
        let n = 50_000;
        let m1 = (0..n).map(|_| r.poisson(3.0)).sum::<u64>() as f64 / n as f64;
        assert!((m1 - 3.0).abs() < 0.05, "m1 {m1}");
        let m2 = (0..n).map(|_| r.poisson(100.0)).sum::<u64>() as f64 / n as f64;
        assert!((m2 - 100.0).abs() < 0.5, "m2 {m2}");
    }

    #[test]
    fn guided_lookup_matches_binary_search() {
        // The guide-table fast path must resolve every u to exactly the
        // index the plain binary search gives — that is what keeps gate
        // draws bit-identical across the hot-path optimization.
        for s in [0.0, 0.4, 1.2, 2.5] {
            for n in [1usize, 2, 7, 160, 1000] {
                let z = Zipf::new(n, s);
                let mut rng = Rng::seed_from_u64(991);
                let reference = |u: f64| -> usize {
                    match z.cdf.binary_search_by(|p| p.total_cmp(&u)) {
                        Ok(i) => i,
                        Err(i) => i.min(z.cdf.len() - 1),
                    }
                };
                // Random draws, the exact CDF boundaries (± 1 ulp), and
                // the exact bucket edges (± 1 ulp) — the 1-ulp cases are
                // where a naive threshold-built guide table over-skips.
                for _ in 0..2000 {
                    let u = rng.f64();
                    assert_eq!(z.index_of(u), reference(u), "n={n} s={s} u={u}");
                }
                let ulp_up = |x: f64| f64::from_bits(x.to_bits() + 1);
                let ulp_down = |x: f64| {
                    if x > 0.0 {
                        f64::from_bits(x.to_bits() - 1)
                    } else {
                        x
                    }
                };
                for i in 0..n {
                    for u in [ulp_down(z.cdf[i]), z.cdf[i], ulp_up(z.cdf[i]).min(1.0)] {
                        assert_eq!(z.index_of(u), reference(u), "cdf edge n={n} s={s} i={i}");
                    }
                }
                let buckets = z.guide.len();
                for b in 1..buckets.min(64) {
                    let edge = b as f64 / buckets as f64;
                    for u in [ulp_down(edge), edge, ulp_up(edge)] {
                        assert_eq!(z.index_of(u), reference(u), "bucket edge n={n} s={s} b={b}");
                    }
                }
                assert_eq!(z.index_of(0.0), reference(0.0));
            }
        }
    }

    #[test]
    fn zipf_uniform_when_s0() {
        let z = Zipf::new(10, 0.0);
        for i in 0..10 {
            assert!((z.pmf(i) - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn zipf_skew_orders_mass() {
        let z = Zipf::new(16, 1.2);
        for i in 1..16 {
            assert!(z.pmf(i) < z.pmf(i - 1));
        }
        let mut r = Rng::seed_from_u64(31);
        let mut counts = [0usize; 16];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[15] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(37);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::seed_from_u64(41);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted_index(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 6);
    }
}
