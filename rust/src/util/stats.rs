//! Small statistics helpers shared by the simulator, metrics, and benches.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; q in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, q)
}

/// Percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Max of a slice (NaN-free inputs assumed); 0.0 for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Streaming mean/max/count accumulator (used on hot paths to avoid
/// storing every sample).
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    pub count: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator {
            count: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            min: f64::INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.min {
            self.min = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Streaming time-weighted mean accumulator: each sample carries its
/// own weight (e.g. the duration it was observed for), so irregularly
/// spaced samples — decode steps of varying length — average by
/// exposure time instead of by count.
#[derive(Clone, Debug, Default)]
pub struct WeightedAccumulator {
    pub weight: f64,
    pub sum: f64,
}

impl WeightedAccumulator {
    pub fn new() -> Self {
        WeightedAccumulator {
            weight: 0.0,
            sum: 0.0,
        }
    }

    /// Observe `x` for weight `w` (non-positive weights are ignored —
    /// a zero-length step contributes no exposure).
    pub fn push(&mut self, x: f64, w: f64) {
        if w > 0.0 {
            self.weight += w;
            self.sum += x * w;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.weight > 0.0 {
            self.sum / self.weight
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(percentile(&xs, 50.0), 30.0);
        assert!((percentile(&xs, 25.0) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [50.0, 10.0, 30.0, 20.0, 40.0];
        assert_eq!(percentile(&xs, 50.0), 30.0);
    }

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_nan_inputs_do_not_panic() {
        // total_cmp sorts NaN above +inf (positive NaN bit patterns),
        // so NaN-poisoned input degrades gracefully instead of
        // panicking mid-sweep: low percentiles still reflect the real
        // samples, and the max percentile surfaces the NaN.
        let xs = [f64::NAN, 30.0, 10.0, 20.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert!((percentile(&xs, 100.0 / 3.0) - 20.0).abs() < 1e-9);
        assert!(percentile(&xs, 100.0).is_nan());
        let all_nan = [f64::NAN, f64::NAN];
        assert!(percentile(&all_nan, 50.0).is_nan());
    }

    #[test]
    fn total_cmp_sort_matches_partial_cmp_for_non_nan() {
        // The total_cmp sweep must not change behavior for ordinary
        // inputs: for NaN-free data (duplicates and infinities
        // included), a total_cmp sort is bit-identical to the old
        // partial_cmp().unwrap() sort.
        let xs = [
            3.5,
            -2.0,
            3.5,
            0.0,
            f64::INFINITY,
            1e-300,
            -1e300,
            f64::NEG_INFINITY,
            7.25,
        ];
        let mut by_total: Vec<f64> = xs.to_vec();
        by_total.sort_by(f64::total_cmp);
        let mut by_partial: Vec<f64> = xs.to_vec();
        by_partial.sort_by(|a, b| {
            // tidy:allow(no-nan-order): the old ordering is the reference here
            a.partial_cmp(b).unwrap()
        });
        let total_bits: Vec<u64> = by_total.iter().map(|x| x.to_bits()).collect();
        let partial_bits: Vec<u64> = by_partial.iter().map(|x| x.to_bits()).collect();
        assert_eq!(total_bits, partial_bits);
    }

    #[test]
    fn accumulator_tracks() {
        let mut a = Accumulator::new();
        for x in [3.0, 1.0, 2.0] {
            a.push(x);
        }
        assert_eq!(a.count, 3);
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.max, 3.0);
        assert_eq!(a.min, 1.0);
    }

    #[test]
    fn weighted_accumulator_weighs_by_exposure() {
        let mut a = WeightedAccumulator::new();
        // 10.0 observed for 3 s, 2.0 for 1 s: mean = 32/4 = 8.
        a.push(10.0, 3.0);
        a.push(2.0, 1.0);
        assert_eq!(a.mean(), 8.0);
        // Non-positive weights contribute nothing.
        a.push(1000.0, 0.0);
        a.push(1000.0, -1.0);
        assert_eq!(a.mean(), 8.0);
    }

    #[test]
    fn weighted_accumulator_empty_is_zero() {
        assert_eq!(WeightedAccumulator::new().mean(), 0.0);
    }
}
