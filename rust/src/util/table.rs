//! Plain-text table rendering for the figure/table regeneration harness.
//! Every `figures <id>` subcommand prints its rows through this, so the
//! output matches the paper's tables/series format consistently.

/// A simple column-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            r.len(),
            self.header.len(),
            "row width {} != header width {}",
            r.len(),
            self.header.len()
        );
        self.rows.push(r);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = &cells[i];
                // Right-align numeric-looking cells, left-align text.
                let numeric = c
                    .chars()
                    .next()
                    .map(|ch| ch.is_ascii_digit() || ch == '-' || ch == '+' || ch == '.')
                    .unwrap_or(false);
                if numeric {
                    line.push_str(&format!("{:>width$}", c, width = widths[i]));
                } else {
                    line.push_str(&format!("{:<width$}", c, width = widths[i]));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals, trimming "-0.0".
pub fn fnum(x: f64, decimals: usize) -> String {
    let s = format!("{:.*}", decimals, x);
    if s.starts_with("-0.") && s[3..].chars().all(|c| c == '0') {
        s[1..].to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "value"]);
        t.row(["alpha", "1.0"]);
        t.row(["b", "22.5"]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn fnum_trims_negative_zero() {
        assert_eq!(fnum(-0.0001, 2), "0.00");
        assert_eq!(fnum(3.14159, 2), "3.14");
    }
}
