//! Arrival processes (BurstGPT-like).
//!
//! BurstGPT shows that production LLM arrivals are burstier than Poisson:
//! the arrival *rate* itself fluctuates. We model a doubly-stochastic
//! (Cox) process — a Gamma-modulated Poisson — whose coefficient of
//! variation exceeds 1, plus a plain Poisson baseline.

use crate::util::rng::Rng;

/// Per-interval request-count generator.
pub trait ArrivalProcess {
    /// Number of requests arriving in an interval of `dt` seconds given a
    /// mean rate `rate` (req/s).
    fn arrivals(&self, rng: &mut Rng, rate: f64, dt: f64) -> u64;
}

/// Plain Poisson arrivals.
#[derive(Clone, Copy, Debug, Default)]
pub struct Poisson;

impl ArrivalProcess for Poisson {
    fn arrivals(&self, rng: &mut Rng, rate: f64, dt: f64) -> u64 {
        rng.poisson(rate * dt)
    }
}

/// Gamma-modulated Poisson (BurstGPT-like burstiness): each interval's
/// rate is Gamma(shape=1/cv², scale=rate·cv²) so E[rate] = rate and the
/// rate's squared coefficient of variation is `cv2`.
#[derive(Clone, Copy, Debug)]
pub struct BurstyPoisson {
    /// Squared coefficient of variation of the modulating rate (>0).
    pub cv2: f64,
}

impl BurstyPoisson {
    pub fn new(cv2: f64) -> Self {
        assert!(cv2 > 0.0);
        BurstyPoisson { cv2 }
    }

    /// Non-panicking constructor for caller-supplied configuration.
    /// (The simulator pre-validates `burst_cv2` in its scenario
    /// `validate()` methods and then uses `new`; use this when wiring
    /// user input straight into an arrival process.)
    pub fn try_new(cv2: f64) -> Result<Self, String> {
        if cv2.is_finite() && cv2 > 0.0 {
            Ok(BurstyPoisson { cv2 })
        } else {
            Err(format!("burstiness cv² must be a positive finite number, got {cv2}"))
        }
    }

    /// Calibration loosely matched to BurstGPT's reported burstiness.
    pub fn burstgpt_like() -> Self {
        BurstyPoisson { cv2: 0.5 }
    }
}

impl ArrivalProcess for BurstyPoisson {
    fn arrivals(&self, rng: &mut Rng, rate: f64, dt: f64) -> u64 {
        if rate <= 0.0 {
            return 0;
        }
        let shape = 1.0 / self.cv2;
        let modulated = rng.gamma(shape, rate * self.cv2);
        rng.poisson(modulated * dt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn moments<P: ArrivalProcess>(p: &P, rate: f64, dt: f64, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Rng::seed_from_u64(seed);
        let xs: Vec<f64> = (0..n).map(|_| p.arrivals(&mut rng, rate, dt) as f64).collect();
        (stats::mean(&xs), stats::variance(&xs))
    }

    #[test]
    fn poisson_mean_matches_rate() {
        let (m, v) = moments(&Poisson, 50.0, 1.0, 20_000, 1);
        assert!((m - 50.0).abs() < 0.5, "mean {m}");
        assert!((v - 50.0).abs() / 50.0 < 0.1, "var {v}");
    }

    #[test]
    fn bursty_is_overdispersed() {
        // Cox process: Var = mean + mean²·cv² > mean.
        let (m, v) = moments(&BurstyPoisson::new(0.5), 50.0, 1.0, 20_000, 2);
        assert!((m - 50.0).abs() < 1.0, "mean {m}");
        let expected_var = 50.0 + 50.0_f64.powi(2) * 0.5;
        assert!(
            (v - expected_var).abs() / expected_var < 0.15,
            "var {v} vs {expected_var}"
        );
    }

    #[test]
    fn zero_rate_yields_zero() {
        let mut rng = Rng::seed_from_u64(3);
        assert_eq!(BurstyPoisson::new(0.5).arrivals(&mut rng, 0.0, 1.0), 0);
    }

    #[test]
    fn try_new_rejects_degenerate_cv2() {
        assert!(BurstyPoisson::try_new(0.5).is_ok());
        assert!(BurstyPoisson::try_new(0.0).is_err());
        assert!(BurstyPoisson::try_new(-1.0).is_err());
        assert!(BurstyPoisson::try_new(f64::NAN).is_err());
    }
}
