//! Request SLO classes (the workload side of `sim::admission`).
//!
//! Production MoE serving mixes request populations with very different
//! latency expectations — interactive chat, standard API traffic, and
//! offline batch jobs. The admission subsystem schedules across these
//! classes; this module defines the class alphabet and the seeded mix a
//! workload draws each arriving request's class from.

use crate::util::rng::Rng;

/// Number of SLO classes. Every per-class accounting surface
/// (`metrics::ClassStats` arrays, the engine's per-class counters) is
/// indexed by [`Priority::rank`] in `0..NUM_CLASSES`.
pub const NUM_CLASSES: usize = 3;

/// A request's SLO class, ordered from most to least latency-sensitive.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Chat-style traffic with a tight time-to-first-token expectation.
    Interactive,
    /// Standard API traffic.
    Standard,
    /// Offline/batch traffic: throughput matters, latency barely does.
    Batch,
}

impl Priority {
    /// Every class, in rank order (most latency-sensitive first).
    pub const ALL: [Priority; NUM_CLASSES] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// Scheduling rank: 0 is the most latency-sensitive class. Lower
    /// ranks are admitted first and preempted last.
    #[inline]
    pub fn rank(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    /// Inverse of [`Self::rank`] (panics out of range).
    pub fn from_rank(rank: usize) -> Self {
        Self::ALL[rank]
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }
}

/// Seeded class mix: the probability weights a workload draws each
/// request's [`Priority`] from. Weights need not be normalized.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassMix {
    /// Weight per class, indexed by [`Priority::rank`].
    pub weights: [f64; NUM_CLASSES],
}

impl ClassMix {
    /// Production-like default: 30% interactive / 50% standard / 20% batch.
    pub fn default_mix() -> Self {
        ClassMix {
            weights: [0.3, 0.5, 0.2],
        }
    }

    /// Every request in one class (handy for tests and ablations).
    pub fn single(class: Priority) -> Self {
        let mut weights = [0.0; NUM_CLASSES];
        weights[class.rank()] = 1.0;
        ClassMix { weights }
    }

    /// Weights must be finite, non-negative, and not all zero.
    pub fn validate(&self) -> Result<(), String> {
        let mut total = 0.0;
        for (i, &w) in self.weights.iter().enumerate() {
            if !w.is_finite() || w < 0.0 {
                return Err(format!(
                    "class weight [{i}] must be finite and non-negative, got {w}"
                ));
            }
            total += w;
        }
        if total <= 0.0 {
            return Err("class mix needs at least one positive weight".to_string());
        }
        Ok(())
    }

    /// Draw one class (a single `f64` draw from `rng`): cumulative scan
    /// over the weights, so identical seeds give identical class streams.
    pub fn sample(&self, rng: &mut Rng) -> Priority {
        let total: f64 = self.weights.iter().sum();
        let mut target = rng.f64() * total;
        for class in Priority::ALL {
            let w = self.weights[class.rank()];
            if target < w {
                return class;
            }
            target -= w;
        }
        // Rounding can leave target == residual at the upper edge; the
        // last class with any weight takes it.
        *Priority::ALL
            .iter()
            .rev()
            .find(|c| self.weights[c.rank()] > 0.0)
            .unwrap_or(&Priority::Standard)
    }
}

impl Default for ClassMix {
    fn default() -> Self {
        Self::default_mix()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_round_trip() {
        for class in Priority::ALL {
            assert_eq!(Priority::from_rank(class.rank()), class);
        }
        assert_eq!(Priority::Interactive.rank(), 0);
        assert_eq!(Priority::Batch.rank(), NUM_CLASSES - 1);
    }

    #[test]
    fn mix_sampling_matches_weights() {
        let mix = ClassMix::default_mix();
        let mut rng = Rng::seed_from_u64(7);
        let mut counts = [0usize; NUM_CLASSES];
        let n = 50_000;
        for _ in 0..n {
            counts[mix.sample(&mut rng).rank()] += 1;
        }
        for (i, &w) in mix.weights.iter().enumerate() {
            let frac = counts[i] as f64 / n as f64;
            assert!((frac - w).abs() < 0.02, "class {i}: {frac} vs {w}");
        }
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let mix = ClassMix::default_mix();
        let draw = |seed| {
            let mut rng = Rng::seed_from_u64(seed);
            (0..256).map(|_| mix.sample(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42));
        assert_ne!(draw(42), draw(43));
    }

    #[test]
    fn single_class_mix_always_returns_it() {
        let mix = ClassMix::single(Priority::Batch);
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(mix.sample(&mut rng), Priority::Batch);
        }
    }

    #[test]
    fn validation_rejects_degenerate_mixes() {
        assert!(ClassMix::default_mix().validate().is_ok());
        assert!(ClassMix { weights: [0.0; 3] }.validate().is_err());
        assert!(ClassMix {
            weights: [1.0, -0.5, 0.0]
        }
        .validate()
        .is_err());
        assert!(ClassMix {
            weights: [f64::NAN, 1.0, 1.0]
        }
        .validate()
        .is_err());
    }
}
