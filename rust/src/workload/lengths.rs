//! Request length model (ShareGPT-like).
//!
//! ShareGPT conversations have short prompts and long generations; the
//! paper uses avg input 16 / avg output 256 tokens. We model lengths as
//! log-normal (heavy-tailed, strictly positive) calibrated to those means,
//! clamped to sane ranges.

use crate::util::rng::Rng;

/// Sampled request shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestLen {
    pub input_tokens: u32,
    pub output_tokens: u32,
}

/// Log-normal length sampler with configurable means.
#[derive(Clone, Debug)]
pub struct LengthModel {
    mu_in: f64,
    mu_out: f64,
    sigma: f64,
    pub max_input: u32,
    pub max_output: u32,
}

impl LengthModel {
    /// ShareGPT-like: avg in 16 / avg out 256 (paper §5.1).
    pub fn sharegpt() -> Self {
        Self::with_means(16.0, 256.0, 0.6)
    }

    /// Arbitrary means; sigma is the log-space spread.
    /// For log-normal, mean = exp(mu + sigma²/2) ⇒ mu = ln(mean) − sigma²/2.
    pub fn with_means(mean_in: f64, mean_out: f64, sigma: f64) -> Self {
        LengthModel {
            mu_in: mean_in.ln() - sigma * sigma / 2.0,
            mu_out: mean_out.ln() - sigma * sigma / 2.0,
            sigma,
            max_input: 4096,
            max_output: 4096,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> RequestLen {
        let input = rng.lognormal(self.mu_in, self.sigma).round().max(1.0) as u32;
        let output = rng.lognormal(self.mu_out, self.sigma).round().max(1.0) as u32;
        RequestLen {
            input_tokens: input.min(self.max_input),
            output_tokens: output.min(self.max_output),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharegpt_means_match_paper() {
        let m = LengthModel::sharegpt();
        let mut rng = Rng::seed_from_u64(1);
        let n = 50_000;
        let (mut si, mut so) = (0.0, 0.0);
        for _ in 0..n {
            let r = m.sample(&mut rng);
            si += r.input_tokens as f64;
            so += r.output_tokens as f64;
        }
        let (mi, mo) = (si / n as f64, so / n as f64);
        assert!((mi - 16.0).abs() < 1.5, "mean input {mi}");
        assert!((mo - 256.0).abs() < 15.0, "mean output {mo}");
    }

    #[test]
    fn lengths_positive_and_clamped() {
        let mut m = LengthModel::with_means(1000.0, 4000.0, 1.5);
        m.max_output = 2048;
        let mut rng = Rng::seed_from_u64(2);
        for _ in 0..10_000 {
            let r = m.sample(&mut rng);
            assert!(r.input_tokens >= 1);
            assert!(r.output_tokens >= 1 && r.output_tokens <= 2048);
        }
    }
}
