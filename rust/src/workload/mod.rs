//! Workload synthesis (§5.1 workloads + Figs 4/11 traces).
//!
//! The paper drives its evaluation with (a) ShareGPT-derived request
//! lengths (avg input 16, avg output 256), (b) BurstGPT-style bursty
//! arrivals, and (c) one-week/24-hour production traces with diurnal
//! patterns peaking at ~7.5× the mean. None of those datasets ship with
//! this environment, so this module synthesizes statistically matching
//! equivalents (see DESIGN.md substitution table). `classes` adds the
//! SLO-class alphabet + seeded mix the admission subsystem
//! (`sim::admission`) schedules across.

pub mod arrivals;
pub mod classes;
pub mod lengths;
pub mod trace;

pub use arrivals::{ArrivalProcess, BurstyPoisson};
pub use classes::{ClassMix, Priority, NUM_CLASSES};
pub use lengths::{LengthModel, RequestLen};
pub use trace::{DiurnalTrace, Request, TraceConfig};
