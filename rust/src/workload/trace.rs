//! Diurnal production-trace synthesis (Fig 4: one week, peaks ≈ 7.5× the
//! trace-wide mean; Fig 11: 24-hour autoscaling trace).

use crate::util::rng::Rng;

use super::arrivals::{ArrivalProcess, BurstyPoisson};
use super::lengths::{LengthModel, RequestLen};

/// One synthesized request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Arrival time, seconds from trace start.
    pub arrival: f64,
    pub len: RequestLen,
}

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Trace length in hours.
    pub hours: f64,
    /// Mean request rate over the whole trace (req/s).
    pub mean_rate: f64,
    /// Peak-to-mean ratio of the diurnal envelope (paper: ~7.5).
    pub peak_to_mean: f64,
    /// Short-term burstiness (Gamma cv²).
    pub burst_cv2: f64,
    /// Resolution of the rate envelope, seconds.
    pub step: f64,
    pub seed: u64,
}

impl TraceConfig {
    /// Fig 4's one-week trace.
    pub fn one_week() -> Self {
        TraceConfig {
            hours: 24.0 * 7.0,
            mean_rate: 10.0,
            peak_to_mean: 7.5,
            burst_cv2: 0.3,
            step: 60.0,
            seed: 2025,
        }
    }

    /// Fig 11's 24-hour autoscaling trace.
    pub fn one_day() -> Self {
        TraceConfig {
            hours: 24.0,
            mean_rate: 10.0,
            peak_to_mean: 7.5,
            burst_cv2: 0.3,
            step: 60.0,
            seed: 1111,
        }
    }
}

/// A synthesized diurnal trace: a rate envelope plus sampled requests.
#[derive(Clone, Debug)]
pub struct DiurnalTrace {
    pub config: TraceConfig,
    /// Rate envelope (req/s) per step.
    pub envelope: Vec<f64>,
}

impl DiurnalTrace {
    /// Build the envelope: a raised-cosine diurnal cycle shaped so that
    /// peak/mean ≈ `peak_to_mean`, with mild day-to-day amplitude jitter.
    ///
    /// A raised cosine `1 + a·cos` has max/mean = 1 + a ≤ 2, so for higher
    /// ratios we sharpen the day peak with an exponent: envelope ∝
    /// ((1+cos)/2)^p, whose peak/mean ratio grows with p; p is solved
    /// numerically.
    pub fn generate(config: TraceConfig) -> Self {
        let steps = (config.hours * 3600.0 / config.step).round() as usize;
        let p = solve_sharpness(config.peak_to_mean);
        let mut rng = Rng::seed_from_u64(config.seed);
        // Day-level amplitude jitter (weekday/weekend variation).
        let days = (config.hours / 24.0).ceil() as usize;
        let day_scale: Vec<f64> = (0..days.max(1))
            .map(|_| rng.f64_range(0.85, 1.15))
            .collect();
        let mut envelope = Vec::with_capacity(steps);
        let mut sum = 0.0;
        for i in 0..steps {
            let t_hours = i as f64 * config.step / 3600.0;
            let day = (t_hours / 24.0) as usize;
            let phase = 2.0 * std::f64::consts::PI * (t_hours % 24.0) / 24.0;
            // Peak at 14:00, trough at 02:00.
            let base = (1.0 + (phase - 2.0 * std::f64::consts::PI * 14.0 / 24.0).cos()) / 2.0;
            // A small constant floor keeps the overnight trough non-zero
            // (production services never fully idle), preserving the
            // target peak-to-mean ratio to first order.
            let v = (0.03 + 0.97 * base.powf(p))
                * day_scale[day.min(day_scale.len() - 1)];
            sum += v;
            envelope.push(v);
        }
        // Normalize to the requested mean rate.
        let mean = sum / steps as f64;
        for v in envelope.iter_mut() {
            *v *= config.mean_rate / mean;
        }
        DiurnalTrace { config, envelope }
    }

    /// Synthetic linear-ramp trace: the rate climbs from `rate_lo` to
    /// `rate_hi` over `hours`, at `step`-second envelope resolution.
    /// Short-horizon live-decode runs (and tests) use this to exercise
    /// scale-up/scale-down without simulating a full diurnal day
    /// token by token.
    pub fn ramp(hours: f64, step: f64, rate_lo: f64, rate_hi: f64, seed: u64) -> Self {
        let steps = ((hours * 3600.0 / step.max(1e-9)).round() as usize).max(1);
        let envelope: Vec<f64> = (0..steps)
            .map(|i| {
                let frac = if steps == 1 {
                    0.0
                } else {
                    i as f64 / (steps - 1) as f64
                };
                rate_lo + (rate_hi - rate_lo) * frac
            })
            .collect();
        let mean_rate = envelope.iter().sum::<f64>() / steps as f64;
        let peak = envelope.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        DiurnalTrace {
            config: TraceConfig {
                hours,
                mean_rate,
                peak_to_mean: if mean_rate > 0.0 { peak / mean_rate } else { 1.0 },
                burst_cv2: 0.3,
                step,
                seed,
            },
            envelope,
        }
    }

    /// Synthetic flash-crowd trace: a flat `base` rate with a
    /// rectangular burst to `peak` over `[spike_start, spike_end)`
    /// seconds. The closed-loop scaling scenarios use this shape: the
    /// interesting decision is the one right *after* the spike, when a
    /// purely envelope-driven scaler follows the now-quiet forecast and
    /// strands the backlog the spike left behind.
    pub fn flash_crowd(
        hours: f64,
        step: f64,
        base: f64,
        peak: f64,
        spike_start: f64,
        spike_end: f64,
        seed: u64,
    ) -> Self {
        let steps = ((hours * 3600.0 / step.max(1e-9)).round() as usize).max(1);
        let envelope: Vec<f64> = (0..steps)
            .map(|i| {
                let t = i as f64 * step;
                if t >= spike_start && t < spike_end {
                    peak
                } else {
                    base
                }
            })
            .collect();
        let mean_rate = envelope.iter().sum::<f64>() / steps as f64;
        let peak_rate = envelope.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        DiurnalTrace {
            config: TraceConfig {
                hours,
                mean_rate,
                peak_to_mean: if mean_rate > 0.0 {
                    peak_rate / mean_rate
                } else {
                    1.0
                },
                burst_cv2: 0.3,
                step,
                seed,
            },
            envelope,
        }
    }

    /// Peak-to-mean ratio of the envelope.
    pub fn peak_to_mean(&self) -> f64 {
        let mean: f64 =
            self.envelope.iter().sum::<f64>() / self.envelope.len() as f64;
        self.envelope.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Envelope rate at time t (seconds).
    pub fn rate_at(&self, t: f64) -> f64 {
        let i = ((t / self.config.step) as usize).min(self.envelope.len() - 1);
        self.envelope[i]
    }

    /// Mean rate over [t0, t1] (the autoscaler's per-interval demand).
    pub fn mean_rate_in(&self, t0: f64, t1: f64) -> f64 {
        let i0 = ((t0 / self.config.step) as usize).min(self.envelope.len() - 1);
        let i1 = ((t1 / self.config.step) as usize).clamp(i0 + 1, self.envelope.len());
        self.envelope[i0..i1].iter().sum::<f64>() / (i1 - i0) as f64
    }

    /// Sample concrete requests over the whole trace.
    pub fn sample_requests(&self, lengths: &LengthModel) -> Vec<Request> {
        let mut rng = Rng::seed_from_u64(self.config.seed ^ 0xDEAD_BEEF);
        let bursty = BurstyPoisson::new(self.config.burst_cv2);
        let mut out = Vec::new();
        for (i, &rate) in self.envelope.iter().enumerate() {
            let t0 = i as f64 * self.config.step;
            let n = bursty.arrivals(&mut rng, rate, self.config.step);
            for _ in 0..n {
                out.push(Request {
                    arrival: t0 + rng.f64() * self.config.step,
                    len: lengths.sample(&mut rng),
                });
            }
        }
        out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        out
    }
}

/// Solve for the cosine-sharpening exponent p with peak/mean(p) = target.
/// peak/mean of ((1+cos x)/2)^p over a period has the closed form
/// Γ(p+1)·Γ(1/2) / Γ(p + 1/2) ... we just bisect on a numeric integral.
fn solve_sharpness(target: f64) -> f64 {
    assert!(target >= 1.0);
    let ratio = |p: f64| {
        let n = 2048;
        let mut sum = 0.0;
        for i in 0..n {
            let x = 2.0 * std::f64::consts::PI * i as f64 / n as f64;
            sum += ((1.0 + x.cos()) / 2.0).powf(p);
        }
        let mean = sum / n as f64;
        1.0 / mean // peak value is 1.0
    };
    let (mut lo, mut hi) = (0.0, 64.0);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if ratio(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_week_peak_to_mean_near_7_5() {
        let tr = DiurnalTrace::generate(TraceConfig::one_week());
        let r = tr.peak_to_mean();
        assert!((r - 7.5).abs() < 1.2, "peak/mean {r}");
    }

    #[test]
    fn envelope_mean_matches_config() {
        let tr = DiurnalTrace::generate(TraceConfig::one_day());
        let mean: f64 = tr.envelope.iter().sum::<f64>() / tr.envelope.len() as f64;
        assert!((mean - tr.config.mean_rate).abs() / tr.config.mean_rate < 1e-9);
    }

    #[test]
    fn requests_sorted_and_plausible() {
        let mut cfg = TraceConfig::one_day();
        cfg.mean_rate = 2.0;
        let tr = DiurnalTrace::generate(cfg);
        let reqs = tr.sample_requests(&LengthModel::sharegpt());
        assert!(!reqs.is_empty());
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        let expected = 2.0 * 24.0 * 3600.0;
        let n = reqs.len() as f64;
        assert!((n - expected).abs() / expected < 0.15, "count {n} vs {expected}");
    }

    #[test]
    fn diurnal_structure_visible() {
        // 14:00 rate should far exceed 02:00 rate.
        let tr = DiurnalTrace::generate(TraceConfig::one_day());
        let afternoon = tr.rate_at(14.0 * 3600.0);
        let night = tr.rate_at(2.0 * 3600.0);
        assert!(afternoon > 5.0 * (night + 1e-9), "{afternoon} vs {night}");
    }

    #[test]
    fn ramp_trace_spans_requested_rates() {
        let tr = DiurnalTrace::ramp(0.5, 60.0, 2.0, 20.0, 7);
        assert_eq!(tr.envelope.len(), 30);
        assert!((tr.rate_at(0.0) - 2.0).abs() < 1e-9);
        assert!((tr.rate_at(0.5 * 3600.0) - 20.0).abs() < 1e-9);
        assert!((tr.config.mean_rate - 11.0).abs() < 1e-9);
        assert!(tr.mean_rate_in(0.0, 600.0) < tr.mean_rate_in(1200.0, 1800.0));
    }

    #[test]
    fn flash_crowd_trace_is_rectangular() {
        // 240 s at 10 s resolution, base 1 req/s, 30 req/s over [10, 50).
        let tr = DiurnalTrace::flash_crowd(240.0 / 3600.0, 10.0, 1.0, 30.0, 10.0, 50.0, 7);
        assert_eq!(tr.envelope.len(), 24);
        assert!((tr.rate_at(0.0) - 1.0).abs() < 1e-12);
        assert!((tr.rate_at(10.0) - 30.0).abs() < 1e-12);
        assert!((tr.rate_at(49.9) - 30.0).abs() < 1e-12);
        assert!((tr.rate_at(50.0) - 1.0).abs() < 1e-12);
        assert!((tr.rate_at(200.0) - 1.0).abs() < 1e-12);
        // Interval means: the spike lives entirely inside [0, 60).
        assert!(tr.mean_rate_in(0.0, 60.0) > 20.0);
        assert!((tr.mean_rate_in(60.0, 120.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_rate_in_interval() {
        let tr = DiurnalTrace::generate(TraceConfig::one_day());
        let m = tr.mean_rate_in(13.0 * 3600.0, 15.0 * 3600.0);
        assert!(m > tr.config.mean_rate, "afternoon window above mean");
    }
}
