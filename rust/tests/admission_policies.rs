//! Behavioral + determinism pins for the `sim::admission` subsystem
//! (tier-1).
//!
//! The acceptance contract of the admission PR:
//!
//! - with `SloClass`/`KvAware` under an overload trace, high-class
//!   (interactive) TTFT-SLO attainment strictly exceeds FIFO's while
//!   aggregate throughput stays within 5%;
//! - `KvAware` preempts lowest-class decodes under KV pressure and the
//!   run stays bit-deterministic;
//! - starvation aging keeps low classes served under a high-class flood.
//!
//! All scenarios run on the scripted `MockServingSystem` (constant step
//! time, explicit capacities) so the pins are about the admission
//! subsystem, not the serving-system models — those are covered by the
//! `admission.tsv` golden snapshot.

use janus::config::serving::Slo;
use janus::sim::admission::{AdmissionConfig, PolicyKind};
use janus::sim::engine::{self, AutoscaleResult, AutoscaleScenario};
use janus::testing::MockServingSystem;
use janus::workload::classes::Priority;
use janus::workload::trace::DiurnalTrace;

const SEED: u64 = 20260727;

/// ~2× overload: capacity 8 at 62.5 ms/step serves 128 tok/s; 8 req/s
/// at ~32 output tokens each offers ~256 tok/s. The bounded queue backs
/// up, so FIFO queue waits (≈ queue / release rate ≈ 16 s) blow through
/// the 1 s TTFT target, while the interactive share alone (~30% ≈ 77
/// tok/s) fits the capacity — the class-aware policies serve it within
/// a couple of slot releases (~0.25 s apart).
fn overload_scenario(policy: PolicyKind) -> AutoscaleScenario {
    let trace = DiurnalTrace::ramp(240.0 / 3600.0, 30.0, 8.0, 8.0, 11);
    let mut sc = AutoscaleScenario::new(60.0, 32.0, Slo::from_ms(300.0), trace);
    sc.queue_capacity = 64;
    sc.admission = AdmissionConfig::with_policy(policy);
    sc
}

fn run_overload(policy: PolicyKind) -> AutoscaleResult {
    let mut sys = MockServingSystem::new(4, 8, 0.0625);
    engine::autoscale(&mut sys, &overload_scenario(policy), SEED).expect("valid scenario")
}

#[test]
fn high_class_attainment_beats_fifo_within_throughput_budget() {
    let fifo = run_overload(PolicyKind::Fifo);
    assert_eq!(fifo.policy, "fifo");
    let interactive = Priority::Interactive.rank();
    // The overload must actually hurt FIFO's interactive class,
    // otherwise the comparison is vacuous.
    let fifo_att = fifo.per_class[interactive]
        .ttft_attainment()
        .expect("FIFO served interactive traffic");
    assert!(
        fifo_att < 0.5,
        "overload too mild: FIFO interactive TTFT attainment {fifo_att}"
    );
    for policy in [PolicyKind::SloClass, PolicyKind::KvAware] {
        let r = run_overload(policy);
        let att = r.per_class[interactive]
            .ttft_attainment()
            .expect("policy served interactive traffic");
        assert!(
            att > fifo_att,
            "{}: interactive TTFT attainment {att} must strictly exceed FIFO's {fifo_att}",
            r.policy
        );
        // Aggregate throughput within 5% of FIFO's.
        let (f, g) = (fifo.generated_tokens as f64, r.generated_tokens as f64);
        assert!(
            (g - f).abs() <= 0.05 * f,
            "{}: generated {g} vs FIFO {f} drifts > 5%",
            r.policy
        );
        // Priority admission reorders service, it must not lose work.
        assert!(r.completed_requests > 0, "{}", r.policy);
    }
}

#[test]
fn per_class_counters_are_consistent() {
    for policy in PolicyKind::ALL {
        let r = run_overload(policy);
        let sum = |f: fn(&janus::metrics::ClassStats) -> u64| -> u64 {
            r.per_class.iter().map(f).sum()
        };
        assert_eq!(sum(|c| c.admitted) as usize, r.admitted_requests, "{}", r.policy);
        assert_eq!(sum(|c| c.rejected) as usize, r.rejected_requests, "{}", r.policy);
        assert_eq!(sum(|c| c.completed) as usize, r.completed_requests, "{}", r.policy);
        assert_eq!(sum(|c| c.preempted) as usize, r.preemptions, "{}", r.policy);
        assert_eq!(sum(|c| c.tokens) as usize, r.generated_tokens, "{}", r.policy);
        assert!(sum(|c| c.first_tokens) >= sum(|c| c.completed), "{}", r.policy);
        for c in &r.per_class {
            assert!(c.ttft_ok <= c.first_tokens);
            assert!(c.tokens_ok <= c.tokens);
        }
    }
}

#[test]
fn every_policy_is_bit_deterministic() {
    let fingerprint = |r: &AutoscaleResult| -> Vec<u64> {
        let mut v = vec![
            r.gpu_hours.to_bits(),
            r.tpot_mean.to_bits(),
            r.ttft_p99.to_bits(),
            r.admission_delay_p99.to_bits(),
            r.slo_attainment.to_bits(),
            r.steps as u64,
            r.admitted_requests as u64,
            r.completed_requests as u64,
            r.rejected_requests as u64,
            r.generated_tokens as u64,
            r.preemptions as u64,
        ];
        for c in &r.per_class {
            v.extend([c.admitted, c.completed, c.rejected, c.preempted, c.ttft_ok]);
        }
        v
    };
    for policy in PolicyKind::ALL {
        let a = fingerprint(&run_overload(policy));
        let b = fingerprint(&run_overload(policy));
        assert_eq!(a, b, "{} not bit-deterministic", policy.name());
    }
}

#[test]
fn kv_aware_preempts_lowest_classes_under_kv_pressure() {
    // Long decodes (mean 64 output tokens) against a 160-token KV
    // budget: resident context outgrows capacity mid-decode, forcing
    // preemption; preempted requests must still complete after their
    // recompute prefill.
    let trace = DiurnalTrace::ramp(90.0 / 3600.0, 30.0, 1.0, 1.0, 13);
    let mut sc = AutoscaleScenario::new(45.0, 64.0, Slo::from_ms(300.0), trace);
    sc.queue_capacity = 64;
    sc.admission = AdmissionConfig::with_policy(PolicyKind::KvAware);
    let run = || {
        let mut sys = MockServingSystem::new(4, 4, 0.05).with_kv_capacity(160.0);
        engine::autoscale(&mut sys, &sc, SEED).expect("valid scenario")
    };
    let r = run();
    assert_eq!(r.policy, "kv");
    assert!(r.preemptions > 0, "KV pressure never triggered preemption");
    assert!(r.completed_requests > 0, "preempted work never finished");
    // Same seed ⇒ bit-identical preemption schedule.
    let r2 = run();
    assert_eq!(r.preemptions, r2.preemptions);
    assert_eq!(r.completed_requests, r2.completed_requests);
    assert_eq!(r.ttft_p99.to_bits(), r2.ttft_p99.to_bits());
}

#[test]
fn aging_keeps_low_classes_served_under_high_class_flood() {
    // 4 req/s at ~8 tokens ≈ 32 tok/s offered against 8 tok/s of
    // capacity: interactive traffic alone can saturate the batch, so
    // without aging the batch class would starve outright.
    let trace = DiurnalTrace::ramp(120.0 / 3600.0, 30.0, 4.0, 4.0, 17);
    let mut sc = AutoscaleScenario::new(60.0, 8.0, Slo::from_ms(300.0), trace);
    sc.queue_capacity = 128;
    sc.admission = AdmissionConfig::with_policy(PolicyKind::SloClass);
    sc.admission.aging_secs = 5.0;
    let mut sys = MockServingSystem::new(4, 2, 0.25);
    let r = engine::autoscale(&mut sys, &sc, SEED).expect("valid scenario");
    let batch_rank = Priority::Batch.rank();
    assert!(
        r.per_class[batch_rank].first_tokens > 0,
        "batch class starved despite aging: {:?}",
        r.per_class[batch_rank]
    );
    // And the priority order still holds where it matters: interactive
    // waits less than batch on average (admission order is class-aware).
    assert!(
        r.per_class[Priority::Interactive.rank()]
            .ttft_attainment()
            .expect("interactive class served")
            >= r.per_class[batch_rank]
                .ttft_attainment()
                .expect("batch class served"),
        "aging inverted the priority order"
    );
}

#[test]
fn failure_scenario_supports_all_policies() {
    use janus::sim::engine::FailureScenario;
    for policy in PolicyKind::ALL {
        let mut sc = FailureScenario::new(Slo::from_ms(300.0), 2.0, 8.0, 90.0)
            .with_failure(30.0, 2, 20.0);
        sc.queue_capacity = 64;
        sc.admission = AdmissionConfig::with_policy(policy);
        let run = || {
            let mut sys = MockServingSystem::new(4, 2, 0.25);
            engine::failure_injection(&mut sys, &sc, SEED).expect("valid scenario")
        };
        let r = run();
        assert_eq!(r.policy, policy.name());
        assert!(r.steps > 0 && r.completed_requests > 0, "{}", r.policy);
        let sum: u64 = r.per_class.iter().map(|c| c.admitted).sum();
        assert_eq!(sum as usize, r.admitted_requests, "{}", r.policy);
        // Bit-deterministic under every policy.
        let r2 = run();
        assert_eq!(r.tpot.mean().to_bits(), r2.tpot.mean().to_bits());
        assert_eq!(r.admitted_requests, r2.admitted_requests);
    }
}
