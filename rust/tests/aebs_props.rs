//! Property tests for the AEBS scheduler invariants (§3.4), via the
//! in-tree `testing::prop` harness: randomized placements and routing
//! batches, deterministic seeds, failing-seed replay.

use janus::placement::ExpertPlacement;
use janus::routing::gate::{ExpertPopularity, GateSim};
use janus::routing::trace::RoutingBatch;
use janus::scheduler::{aebs, baselines};
use janus::testing::prop;
use janus::util::rng::Rng;

/// A redundant round-robin placement plus a gate over it.
fn random_setup(rng: &mut Rng) -> (ExpertPlacement, GateSim) {
    let experts = 16 + rng.usize_below(64);
    let top_k = 2 + rng.usize_below(5); // 2..=6, experts ≥ 16
    let n_inst = 2 + rng.usize_below(8);
    // At least one spare slot per instance so real replica choice exists.
    let capacity = experts.div_ceil(n_inst) + 1 + rng.usize_below(4);
    let placement = ExpertPlacement::round_robin(experts, n_inst, capacity);
    let skew = rng.f64_range(0.0, 1.5);
    let gate = GateSim::new(experts, top_k, &ExpertPopularity::Zipf { s: skew }, rng);
    (placement, gate)
}

fn sample(rng: &mut Rng, gate: &GateSim, min_tokens: usize) -> RoutingBatch {
    gate.sample_batch(rng, min_tokens + rng.usize_below(192))
}

/// Every activated logical expert is served by exactly one hosting
/// replica: all of its requests land on a single instance, and that
/// instance hosts it (splitting would raise Σ a_g — the defect AEBS
/// exists to avoid).
#[test]
fn every_activated_expert_gets_exactly_one_replica() {
    prop::check("one replica per activated expert", 40, |rng| {
        let (placement, gate) = random_setup(rng);
        let batch = sample(rng, &gate, 32);
        let asg = aebs::assign(&batch, &placement);
        let mut chosen: Vec<Option<u32>> = vec![None; batch.experts];
        for (&e, &g) in batch.flat().iter().zip(asg.instance_of.iter()) {
            assert!(
                placement.hosts(e).contains(&g),
                "expert {e} routed to non-hosting instance {g}"
            );
            match chosen[e as usize] {
                None => chosen[e as usize] = Some(g),
                Some(prev) => assert_eq!(
                    prev, g,
                    "expert {e} split across replicas {prev} and {g}"
                ),
            }
        }
    });
}

/// Structural validity: the assignment's cached load metrics survive a
/// from-scratch recount against the batch and placement.
#[test]
fn assignments_respect_placement_and_metrics() {
    prop::check("assignment validity", 40, |rng| {
        let (placement, gate) = random_setup(rng);
        let batch = sample(rng, &gate, 16);
        let asg = aebs::assign(&batch, &placement);
        asg.validate(&batch, &placement).unwrap();
        assert_eq!(asg.loads.len(), placement.n_instances);
        assert_eq!(
            asg.loads.iter().copied().max().unwrap_or(0),
            asg.a_max
        );
    });
}

/// Deterministic tie-breaking: identical inputs produce an identical
/// `Assignment` — the property that lets every MoE instance run AEBS
/// redundantly without synchronization (§3.4), and that the engine's
/// seeded-determinism contract inherits.
#[test]
fn aebs_is_deterministic_on_identical_inputs() {
    prop::check("deterministic tie-breaking", 40, |rng| {
        let (placement, gate) = random_setup(rng);
        let batch = sample(rng, &gate, 16);
        let a1 = aebs::assign(&batch, &placement);
        let a2 = aebs::assign(&batch, &placement);
        assert_eq!(a1, a2, "same inputs must yield the identical Assignment");
        // And through a reused workspace (the hot-path entry point).
        let mut ws = aebs::Workspace::new(batch.experts, placement.n_instances);
        let w1 = aebs::assign_with(&mut ws, &batch, &placement);
        let _ = aebs::assign_with(&mut ws, &gate.sample_batch(rng, 64), &placement);
        let w2 = aebs::assign_with(&mut ws, &batch, &placement);
        assert_eq!(w1, w2, "workspace reuse must not perturb decisions");
        assert_eq!(w1, a1);
    });
}

/// AEBS never loses to EPLB-style token balancing on the straggler
/// metric: summed over several online-scale batches per case,
/// a_max(AEBS) ≤ a_max(token_balanced). (Token balancing splits hot
/// experts across replicas, activating them on several instances; at
/// online batch sizes that penalty dominates.)
#[test]
fn aebs_amax_bounded_by_token_balanced() {
    prop::check("a_max(AEBS) ≤ a_max(EPLB)", 40, |rng| {
        let (placement, gate) = random_setup(rng);
        let mut sum_aebs = 0u64;
        let mut sum_tb = 0u64;
        for _ in 0..4 {
            let batch = sample(rng, &gate, 64);
            sum_aebs += aebs::assign(&batch, &placement).a_max as u64;
            sum_tb += baselines::token_balanced(&batch, &placement).a_max as u64;
        }
        assert!(
            sum_aebs <= sum_tb,
            "AEBS a_max sum {sum_aebs} exceeds token-balanced {sum_tb}"
        );
    });
}
