//! Allocation regression for the decode hot path.
//!
//! The PR-3 hot-path contract: once a serving system is configured and
//! its reusable buffers are warm, `JanusSystem::step` performs ZERO heap
//! allocations per simulated decode step — the routing batch, the AEBS
//! workspace, and the comm-plan scratch are all reused. The baselines
//! share the same buffer plumbing; they get a loose bound rather than an
//! exact zero so platform quirks can't make the suite brittle.
//!
//! Measured with a counting `#[global_allocator]`. The file holds a
//! single test so no sibling test thread can allocate concurrently and
//! pollute the counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use janus::baselines::{
    JanusSystem, MegaScaleInfer, ServingSystem, SgLang, XDeepServe,
};
use janus::config::hardware::paper_testbed;
use janus::config::models;
use janus::config::serving::Slo;
use janus::obs::{ObsMode, Recorder};
use janus::routing::gate::ExpertPopularity;
use janus::util::rng::Rng;

struct CountingAllocator;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Warm a system's reusable buffers, then count allocations over a
/// steady-state run of decode steps.
fn steady_state_allocs(sys: &mut dyn ServingSystem, batch: usize, steps: usize) -> u64 {
    let mut rng = Rng::seed_from_u64(7);
    // Warm-up: grow the routing buffer, scheduler workspaces, and comm
    // scratch to the working set for this batch.
    for _ in 0..20 {
        std::hint::black_box(sys.step(batch, &mut rng));
    }
    let before = allocations();
    for _ in 0..steps {
        std::hint::black_box(sys.step(batch, &mut rng));
    }
    allocations() - before
}

/// Single test on purpose — see module docs.
#[test]
fn steady_state_decode_steps_do_not_allocate() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 0.4 };
    let slo = Slo::from_ms(200.0);
    const BATCH: usize = 256;
    const STEPS: usize = 1000;

    // The paper's system: exactly zero allocations per steady-state step.
    let mut janus = JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 42);
    janus.configure(BATCH, slo).expect("feasible at B=256");
    let janus_allocs = steady_state_allocs(&mut janus, BATCH, STEPS);
    assert_eq!(
        janus_allocs, 0,
        "JanusSystem::step allocated {janus_allocs} times over {STEPS} \
         steady-state steps — the zero-alloc decode contract is broken"
    );

    // Baselines: the same buffer plumbing, held to a loose bound (< 2
    // allocations per step on average) so an incidental platform alloc
    // can't flake the suite while a real per-step regression still fails.
    let mut sgl = SgLang::build(model.clone(), hw.clone(), &pop, 43);
    let _ = sgl.configure(BATCH, slo);
    let mut msi = MegaScaleInfer::build(model.clone(), hw.clone(), &pop, 16, 44);
    let _ = msi.configure(BATCH, slo);
    let mut xds = XDeepServe::build(model, hw, &pop, 32, 45);
    let _ = xds.configure(BATCH, slo);
    let baselines: [(&str, &mut dyn ServingSystem); 3] = [
        ("SGLang", &mut sgl),
        ("MegaScale-Infer", &mut msi),
        ("xDeepServe", &mut xds),
    ];
    for (name, sys) in baselines {
        let allocs = steady_state_allocs(sys, BATCH, STEPS);
        assert!(
            allocs < 2 * STEPS as u64,
            "{name}::step allocated {allocs} times over {STEPS} steps \
             (bound: < {})",
            2 * STEPS
        );
    }

    // Observability plane: recording every step into a counters-mode
    // recorder, a pre-sized full-mode recorder, AND an off-mode one adds
    // ZERO allocations to the steady-state loop — counters/ledger are
    // fixed arrays, the phase split is pure float arithmetic, and the
    // full-mode event buffer never grows past its pre-sized capacity.
    let mut off = Recorder::new(ObsMode::Off);
    let mut counters = Recorder::new(ObsMode::Counters);
    let mut full = Recorder::with_capacity(ObsMode::Full, 2 * STEPS);
    let mut rng = Rng::seed_from_u64(9);
    let mut record_all = |janus: &mut JanusSystem, t: f64, rng: &mut Rng| {
        let out = janus.step(BATCH, rng);
        let phases = janus.step_phases().reconciled(out.tpot);
        for rec in [&mut off, &mut counters, &mut full] {
            if rec.enabled() {
                rec.decode_step(t, out.tpot, BATCH, out.a_max, &phases, 0.0, 0.0, 0.0);
            }
        }
        out.tpot
    };
    for i in 0..20 {
        std::hint::black_box(record_all(&mut janus, i as f64, &mut rng));
    }
    let before = allocations();
    for i in 0..STEPS {
        std::hint::black_box(record_all(&mut janus, (20 + i) as f64, &mut rng));
    }
    let obs_allocs = allocations() - before;
    assert_eq!(
        obs_allocs, 0,
        "recording {STEPS} decode steps (off + counters + pre-sized full) \
         allocated {obs_allocs} times — the zero-alloc telemetry contract \
         is broken"
    );
    assert!(counters.counter(janus::obs::Counter::DecodeSteps) >= STEPS as u64);
    assert_eq!(full.events().len(), 20 + STEPS, "one span per recorded step");

    // Off stays provably inert: nothing counted, nothing buffered, and
    // the same seeded step sequence with and without an off recorder in
    // the loop yields bit-identical charges.
    assert!(off.counters().iter().all(|&c| c == 0));
    assert!(off.events().is_empty());
    assert_eq!(off.ledger().total(), 0.0);
    let replay = |with_recorder: bool| -> Vec<u64> {
        let model = models::deepseek_v2();
        let hw = paper_testbed();
        let pop = ExpertPopularity::Zipf { s: 0.4 };
        let mut sys = JanusSystem::build(model, hw, &pop, 16, 42);
        sys.configure(BATCH, Slo::from_ms(200.0)).expect("feasible");
        let mut rec = Recorder::new(ObsMode::Off);
        let mut rng = Rng::seed_from_u64(21);
        (0..50)
            .map(|i| {
                let out = sys.step(BATCH, &mut rng);
                if with_recorder && rec.enabled() {
                    let phases = sys.step_phases().reconciled(out.tpot);
                    rec.decode_step(i as f64, out.tpot, BATCH, out.a_max, &phases, 0.0, 0.0, 0.0);
                }
                out.tpot.to_bits()
            })
            .collect()
    };
    assert_eq!(replay(false), replay(true), "off-mode recorder perturbed the floats");
}
