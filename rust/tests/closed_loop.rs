//! Tier-1 pins for closed-loop scaling (`scaling::signal`).
//!
//! The acceptance contract of the closed-loop PR: under a flash crowd —
//! a rectangular burst that lives entirely inside one decision interval
//! — a scaler that only follows the arrival-envelope forecast sizes the
//! *next* interval for the now-quiet envelope and strands the backlog
//! the burst left behind, while the closed loop sees that backlog (and
//! the measured token rate) in its [`janus::scaling::ScalingSignal`]
//! and keeps capacity up until the queue drains. At an identical GPU
//! footprint, closed-loop must therefore strictly beat reactive on
//! interactive TTFT attainment — and stay bit-deterministic.
//!
//! Scenarios run on the scripted `MockServingSystem` with its
//! demand→capacity response enabled (one batch slot per 20 tok/s of
//! demanded rate at a *fixed* GPU count), so the pins are about the
//! scaling feedback loop, not the serving-system models.

use janus::config::serving::Slo;
use janus::scaling::ScalingMode;
use janus::sim::admission::AdmissionConfig;
use janus::sim::engine::{self, AutoscaleResult, AutoscaleScenario};
use janus::testing::MockServingSystem;
use janus::workload::classes::{ClassMix, Priority};
use janus::workload::trace::DiurnalTrace;

const SEED: u64 = 20260808;

/// 240 s flash crowd: 1 req/s base with a 60 req/s burst over [10, 50),
/// ~8 output tokens per request, scaling decisions every 60 s. The
/// burst is over before the second decision, so the t = 60 s envelope
/// forecast reads 1 req/s while hundreds of requests still queue.
fn flash_crowd_scenario(mode: ScalingMode) -> AutoscaleScenario {
    let trace = DiurnalTrace::flash_crowd(240.0 / 3600.0, 10.0, 1.0, 60.0, 10.0, 50.0, 19);
    let mut sc = AutoscaleScenario::new(60.0, 8.0, Slo::from_ms(200.0), trace);
    sc.admission = AdmissionConfig::fifo();
    sc.admission.class_mix = ClassMix::single(Priority::Interactive);
    sc.scaling = mode;
    sc
}

fn run_flash_crowd(mode: ScalingMode) -> AutoscaleResult {
    // One batch slot serves one token per 50 ms step = 20 tok/s, so the
    // demand response provisions ceil(demand / 20) slots — at a fixed
    // 4-GPU footprint, so both modes spend identical GPU-hours and only
    // their capacity trajectories differ.
    let mut sys = MockServingSystem::new(4, 8, 0.05).with_demand_response(20.0, 64);
    engine::autoscale(&mut sys, &flash_crowd_scenario(mode), SEED).expect("valid scenario")
}

#[test]
fn closed_loop_beats_reactive_on_flash_crowd_at_equal_gpu_hours() {
    let reactive = run_flash_crowd(ScalingMode::Reactive);
    let closed = run_flash_crowd(ScalingMode::Closed);
    let interactive = Priority::Interactive.rank();

    // Identical footprint: the comparison is policy-only, not capacity.
    assert_eq!(
        reactive.gpu_hours.to_bits(),
        closed.gpu_hours.to_bits(),
        "GPU-hours must match bit-for-bit at a fixed pool"
    );

    let reactive_att = reactive.per_class[interactive]
        .ttft_attainment()
        .expect("reactive run served interactive traffic");
    let closed_att = closed.per_class[interactive]
        .ttft_attainment()
        .expect("closed run served interactive traffic");
    // The flash crowd must actually hurt the envelope-only scaler,
    // otherwise the comparison is vacuous.
    assert!(
        reactive_att < 0.5,
        "flash crowd too mild: reactive interactive TTFT attainment {reactive_att}"
    );
    assert!(
        closed_att > reactive_att + 0.01,
        "closed-loop interactive TTFT attainment {closed_att} must strictly exceed \
         reactive's {reactive_att}"
    );

    // Single-class mix: the idle classes must report absent attainment,
    // not a fake 1.0 (the empty-class bugfix this PR pins).
    for rank in [Priority::Standard.rank(), Priority::Batch.rank()] {
        assert!(reactive.per_class[rank].ttft_attainment().is_none());
        assert!(closed.per_class[rank].ttft_attainment().is_none());
    }

    // Both runs saw the same arrival stream; neither may lose work.
    assert_eq!(reactive.rejected_requests, 0);
    assert_eq!(closed.rejected_requests, 0);
    assert!(closed.completed_requests > 0 && reactive.completed_requests > 0);
}

#[test]
fn closed_loop_flash_crowd_is_bit_deterministic() {
    let fingerprint = |r: &AutoscaleResult| -> Vec<u64> {
        let mut v = vec![
            r.gpu_hours.to_bits(),
            r.feasible_fraction.to_bits(),
            r.tpot_mean.to_bits(),
            r.ttft_p99.to_bits(),
            r.admission_delay_p99.to_bits(),
            r.slo_attainment.to_bits(),
            r.queue_depth_mean.to_bits(),
            r.steps as u64,
            r.admitted_requests as u64,
            r.completed_requests as u64,
            r.rejected_requests as u64,
            r.generated_tokens as u64,
        ];
        for c in &r.per_class {
            v.extend([c.admitted, c.completed, c.rejected, c.first_tokens, c.ttft_ok]);
        }
        v
    };
    let a = fingerprint(&run_flash_crowd(ScalingMode::Closed));
    let b = fingerprint(&run_flash_crowd(ScalingMode::Closed));
    assert_eq!(a, b, "closed-loop run not bit-deterministic");
}
