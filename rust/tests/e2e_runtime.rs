//! Integration tests over the real PJRT runtime + coordinator. They need
//! the `pjrt` cargo feature (the whole file compiles away without it) and
//! `make artifacts` plus the real XLA bindings at run time; they skip
//! gracefully when the artifacts are missing — which keeps the suite
//! green on GPU-less machines and with the vendored XLA stub.
#![cfg(feature = "pjrt")]

use janus::config::hardware::paper_testbed;
use janus::coordinator::Leader;
use janus::placement::ExpertPlacement;
use janus::runtime::artifacts::ArtifactBundle;

fn bundle() -> Option<ArtifactBundle> {
    let dir = ArtifactBundle::default_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactBundle::load(&dir).unwrap())
}

/// Greedy decode produces identical token streams across MoE pool sizes
/// 1/2/3 — the disaggregation-transparency invariant at system level.
#[test]
fn pool_size_transparency_full_sweep() {
    let Some(b0) = bundle() else { return };
    let experts = b0.meta.experts;
    let mut outputs = Vec::new();
    for n_moe in [1usize, 2, 3] {
        let bundle = ArtifactBundle::load(&b0.dir).unwrap();
        let cap = experts.div_ceil(n_moe) + 1;
        let placement = ExpertPlacement::round_robin(experts, n_moe, cap);
        let mut leader = Leader::new(bundle, &placement, &paper_testbed()).unwrap();
        leader.queue.submit(vec![3, 141, 59], 6);
        leader.queue.submit(vec![265], 6);
        leader.queue.submit(vec![271, 828], 6);
        let r = leader.serve(64).unwrap();
        assert_eq!(r.completed_requests, 3);
        let mut c = r.completions.clone();
        c.sort_by_key(|(id, _)| *id);
        outputs.push(c);
    }
    assert_eq!(outputs[0], outputs[1]);
    assert_eq!(outputs[1], outputs[2]);
}

/// Continuous batching: more requests than slots — later requests admit
/// as earlier ones finish, and everything completes.
#[test]
fn continuous_batching_oversubscribed() {
    let Some(b) = bundle() else { return };
    let experts = b.meta.experts;
    let slots = b.meta.batch_tokens;
    let placement = ExpertPlacement::round_robin(experts, 2, experts / 2 + 1);
    let mut leader = Leader::new(b, &placement, &paper_testbed()).unwrap();
    let n = slots * 2 + 3;
    for i in 0..n {
        leader.queue.submit(vec![(i % 400) as i32 + 1], 3);
    }
    let r = leader.serve(500).unwrap();
    assert_eq!(r.completed_requests, n);
    assert_eq!(r.generated_tokens, n * 3);
    assert!(leader.queue.is_empty());
}

/// Long generation exercises KV growth up to the context limit without
/// corruption (lengths clamp at max_ctx - 1).
#[test]
fn long_generation_within_context() {
    let Some(b) = bundle() else { return };
    let experts = b.meta.experts;
    let max_new = b.meta.max_ctx - 4;
    let placement = ExpertPlacement::round_robin(experts, 2, experts / 2 + 1);
    let mut leader = Leader::new(b, &placement, &paper_testbed()).unwrap();
    leader.queue.submit(vec![7, 8, 9], max_new);
    let r = leader.serve(200).unwrap();
    assert_eq!(r.completed_requests, 1);
    assert_eq!(r.completions[0].1.len(), max_new);
}

/// Mixed prompt lengths in one batch (ragged prefill through the decode
/// path) all complete with the right output counts.
#[test]
fn ragged_prompts_complete() {
    let Some(b) = bundle() else { return };
    let experts = b.meta.experts;
    let placement = ExpertPlacement::round_robin(experts, 3, experts / 3 + 2);
    let mut leader = Leader::new(b, &placement, &paper_testbed()).unwrap();
    let specs = [(1usize, 2usize), (5, 4), (2, 7), (9, 1)];
    for (plen, out) in specs {
        let prompt: Vec<i32> = (1..=plen as i32).collect();
        leader.queue.submit(prompt, out);
    }
    let r = leader.serve(200).unwrap();
    assert_eq!(r.completed_requests, specs.len());
    let mut c = r.completions.clone();
    c.sort_by_key(|(id, _)| *id);
    for ((_, toks), (_, out)) in c.iter().zip(specs.iter()) {
        assert_eq!(toks.len(), *out);
    }
}
