//! Ordering-equivalence property tests for the event queue.
//!
//! The event-queue contract (documented on `Entry::key_cmp` in
//! `sim::engine`): events dequeue in strictly ascending `(time, seq)`
//! order — `time` by `total_cmp`, `seq` the global insertion counter —
//! so equal-timestamp events come out FIFO. The production calendar
//! queue (`EventQueue`) must realize exactly the stream the reference
//! `BinaryHeapEventQueue` produces: same Event stream in → same Event
//! stream out, including tie order, under arbitrary interleavings of
//! pushes (clustered, tied, far-future, behind-the-scan-point) and pops.

use janus::sim::engine::{BinaryHeapEventQueue, Event, EventKind, EventQueue};
use janus::testing::prop::check;
use janus::util::rng::Rng;

fn assert_same_event(a: Option<Event>, b: Option<Event>, ctx: &str) {
    match (&a, &b) {
        (None, None) => {}
        (Some(x), Some(y)) => {
            assert_eq!(
                x.time.to_bits(),
                y.time.to_bits(),
                "{ctx}: calendar t={} vs heap t={}",
                x.time,
                y.time
            );
            assert_eq!(x.kind, y.kind, "{ctx}: kinds diverged at t={}", x.time);
        }
        _ => panic!("{ctx}: one queue drained early (cal={a:?}, heap={b:?})"),
    }
}

/// Push the same event into both queues; the payload id makes every
/// event distinguishable so a tie-order swap cannot hide.
fn push_both(
    cal: &mut EventQueue,
    heap: &mut BinaryHeapEventQueue,
    time: f64,
    id: &mut u32,
) {
    let kind = EventKind::probe_arrival(*id);
    *id += 1;
    cal.push(time, kind.clone());
    heap.push(time, kind);
}

#[test]
fn calendar_queue_matches_heap_event_for_event() {
    check("calendar ≡ heap under random interleavings", 200, |rng| {
        let mut cal = EventQueue::new();
        let mut heap = BinaryHeapEventQueue::new();
        let mut id = 0u32;
        // `now` tracks the last dequeued time, as a simulation loop
        // would; pushes land around it in the regimes the scenarios
        // generate (plus behind it, which the API also permits).
        let mut now = 0.0f64;
        let ops = 1 + rng.usize_below(300);
        for op in 0..ops {
            if rng.f64() < 0.6 {
                let base = match rng.usize_below(5) {
                    // Exact tie with the current time.
                    0 => now,
                    // Clustered near future — the continuous-batching
                    // hot case (decode steps ms apart).
                    1 => now + rng.f64() * 1e-3,
                    // Within the next arrival window.
                    2 => now + rng.f64(),
                    // Far future (recovery/scaling-decision scale).
                    3 => now + rng.f64() * 5000.0,
                    // Behind the scan point.
                    _ => now * rng.f64(),
                };
                // Bursts share a base time so equal-timestamp FIFO
                // ordering is exercised constantly.
                let burst = 1 + rng.usize_below(6);
                for _ in 0..burst {
                    let t = if rng.bool_with(0.5) {
                        base
                    } else {
                        base + rng.f64() * 1e-4
                    };
                    push_both(&mut cal, &mut heap, t, &mut id);
                }
            } else {
                let (a, b) = (cal.pop(), heap.pop());
                if let Some(e) = &a {
                    now = now.max(e.time);
                }
                assert_same_event(a, b, &format!("op {op}"));
            }
            assert_eq!(cal.len(), heap.len(), "op {op}: length diverged");
        }
        // Drain both completely — the full residual streams must match.
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            let done = a.is_none();
            assert_same_event(a, b, "drain");
            if done {
                break;
            }
        }
        assert!(cal.is_empty() && heap.is_empty());
    });
}

#[test]
fn equal_timestamp_bursts_always_fifo() {
    check("equal-timestamp bursts dequeue FIFO", 100, |rng| {
        let mut cal = EventQueue::new();
        // Several bursts at a handful of distinct times, pushed in
        // shuffled time order; within one timestamp, ids are assigned
        // in push order and must come back in exactly that order.
        let mut times: Vec<f64> = (0..1 + rng.usize_below(8))
            .map(|_| rng.f64() * 100.0)
            .collect();
        rng.shuffle(&mut times);
        let mut id = 0u32;
        let mut expected: Vec<(u64, u32)> = Vec::new();
        for &t in &times {
            for _ in 0..1 + rng.usize_below(30) {
                cal.push(t, EventKind::probe_arrival(id));
                expected.push((t.to_bits(), id));
                id += 1;
            }
        }
        // Expected order: ascending time, then insertion (push) order.
        // Sorting by (total_cmp bits of a non-negative f64, push id) is
        // exactly the queue's (time, seq) key for these inputs.
        expected.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        for (i, (t_bits, want_id)) in expected.iter().enumerate() {
            let ev = cal.pop().expect("event present");
            assert_eq!(ev.time.to_bits(), *t_bits, "position {i}");
            assert_eq!(
                ev.kind,
                EventKind::probe_arrival(*want_id),
                "position {i}: tie order broken"
            );
        }
        assert!(cal.pop().is_none());
    });
}

/// Re-tune on a drained-then-refilled queue: the first population tunes
/// the bucket width to millisecond gaps; after a full drain, a refill in
/// a completely different time regime (hour-scale gaps, plus ties) must
/// still dequeue in exact `(time, seq)` order. The stale width from the
/// first life of the queue cannot corrupt the second.
#[test]
fn drained_then_refilled_queue_stays_exact() {
    let mut cal = EventQueue::new();
    let mut heap = BinaryHeapEventQueue::new();
    let mut id = 0u32;
    // Life 1: dense millisecond-scale population, big enough to force
    // growth resizes (and the width re-tune that comes with them).
    for i in 0..200 {
        push_both(&mut cal, &mut heap, i as f64 * 1e-3, &mut id);
    }
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        let done = a.is_none();
        assert_same_event(a, b, "life-1 drain");
        if done {
            break;
        }
    }
    assert!(cal.is_empty());
    // Life 2: sparse hour-scale events with equal-timestamp bursts,
    // pushed out of time order.
    for &t in &[7200.0, 3600.0, 10800.0, 3600.0, 7200.0, 3600.0] {
        push_both(&mut cal, &mut heap, t, &mut id);
    }
    for i in 0..100 {
        push_both(&mut cal, &mut heap, 5000.0 + i as f64 * 3600.0, &mut id);
    }
    let mut popped = 0usize;
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        let done = a.is_none();
        assert_same_event(a, b, &format!("life-2 pop {popped}"));
        if done {
            break;
        }
        popped += 1;
    }
    assert_eq!(popped, 106);
}

/// All-equal-timestamp workload: every event hashes to one bucket, the
/// resize gap list is all zeros (so the width re-tune must not divide by
/// or adopt a zero), and FIFO order must survive growth resizes, shrink
/// resizes, and interleaved pops.
#[test]
fn all_equal_timestamps_single_bucket_stays_fifo() {
    let mut cal = EventQueue::new();
    let mut next_id = 0u32;
    let mut expect_front = 0u32;
    // Push 400 (forces several growth resizes with every entry in one
    // bucket), pop 300 (forces shrink resizes mid-tie-stream), push
    // another burst at the same timestamp, then drain.
    for _ in 0..400 {
        cal.push(42.0, EventKind::probe_arrival(next_id));
        next_id += 1;
    }
    for _ in 0..300 {
        let ev = cal.pop().expect("event");
        assert_eq!(ev.time, 42.0);
        assert_eq!(ev.kind, EventKind::probe_arrival(expect_front));
        expect_front += 1;
    }
    for _ in 0..100 {
        cal.push(42.0, EventKind::probe_arrival(next_id));
        next_id += 1;
    }
    while let Some(ev) = cal.pop() {
        assert_eq!(ev.kind, EventKind::probe_arrival(expect_front));
        expect_front += 1;
    }
    assert_eq!(expect_front, next_id, "events lost or reordered");
}

/// Property pin: heap equivalence holds across *forced* mid-stream
/// resizes — each case pushes enough to guarantee growth resizes, then
/// drains below the shrink threshold, then pushes a second wave into the
/// re-tuned calendar, comparing event-for-event the whole way.
#[test]
fn equivalence_holds_across_forced_midstream_resize() {
    check("calendar ≡ heap across forced resizes", 100, |rng| {
        let mut cal = EventQueue::new();
        let mut heap = BinaryHeapEventQueue::new();
        let mut id = 0u32;
        // Wave 1: > 2×16 events forces at least one growth resize.
        let wave1 = 40 + rng.usize_below(200);
        let spread = [1e-4, 1.0, 1000.0][rng.usize_below(3)];
        for _ in 0..wave1 {
            push_both(&mut cal, &mut heap, rng.f64() * spread, &mut id);
        }
        // Drain to < len/4 of the grown bucket count: forces shrinks.
        let keep = rng.usize_below(8);
        while cal.len() > keep {
            let (a, b) = (cal.pop(), heap.pop());
            assert_same_event(a, b, "forced-shrink drain");
        }
        // Wave 2 in a (possibly) different regime, behind and ahead of
        // the scan point, with ties on a shared base.
        let spread2 = [1e-3, 60.0, 86_400.0][rng.usize_below(3)];
        let base = rng.f64() * spread2;
        for _ in 0..20 + rng.usize_below(60) {
            let t = if rng.bool_with(0.3) {
                base
            } else {
                rng.f64() * spread2
            };
            push_both(&mut cal, &mut heap, t, &mut id);
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            let done = a.is_none();
            assert_same_event(a, b, "final drain");
            if done {
                break;
            }
        }
    });
}

#[test]
fn scenario_shaped_stream_is_identical() {
    // A deterministic facsimile of what a continuous-batching scenario
    // pushes: 1 s arrival windows, per-window arrival bursts, chained
    // decode steps, periodic decisions, one far-future recovery.
    let mut cal = EventQueue::new();
    let mut heap = BinaryHeapEventQueue::new();
    let mut rng = Rng::seed_from_u64(0xCA1E);
    let mut id = 0u32;
    for w in 0..120u32 {
        let t0 = w as f64;
        push_both(&mut cal, &mut heap, t0, &mut id); // window tick
        for _ in 0..rng.usize_below(12) {
            push_both(&mut cal, &mut heap, t0 + rng.f64(), &mut id);
        }
        let mut step_t = t0;
        for _ in 0..rng.usize_below(25) {
            step_t += 0.02 + rng.f64() * 0.05; // TPOT-scale chaining
            push_both(&mut cal, &mut heap, step_t, &mut id);
        }
        if w % 15 == 0 {
            push_both(&mut cal, &mut heap, t0 + 900.0, &mut id);
        }
    }
    push_both(&mut cal, &mut heap, 7200.0, &mut id);
    assert_eq!(cal.len(), heap.len());
    let mut popped = 0usize;
    loop {
        let (a, b) = (cal.pop(), heap.pop());
        let done = a.is_none();
        assert_same_event(a, b, &format!("pop {popped}"));
        if done {
            break;
        }
        popped += 1;
    }
    assert!(popped > 1000, "stream too small to be meaningful: {popped}");
}
