//! Pin for the figure-panel seeding hygiene that preceded the sweep
//! port (threads = 1 throughout — this is about seed derivation, not
//! parallelism).
//!
//! The `figures` panels used to thread ONE mutable RNG through their
//! `for _ in 0..reps` loops, so rep r's stream started wherever rep r-1
//! left off — a cell's value depended on its predecessor having run,
//! which is incompatible with cells as units of isolation. The panels
//! now derive per-rep seeds with `split_seed(panel_id, rep)`. This test
//! replicates one panel cell (Fig 13's AEBS-vs-EPLB a_max measurement)
//! under both schemes and pins:
//!
//! 1. the legacy shared-RNG scheme WAS history-dependent (rep r alone ≠
//!    rep r in sequence) — why the reseed was needed;
//! 2. the derived-seed scheme is history-independent (rep r alone ==
//!    rep r in any sequence, bit-for-bit);
//! 3. with the rep-0 derived seed pinned to the legacy seed, rep 0's
//!    value is identical under both schemes — the reseed is the only
//!    delta, the measured computation is untouched.

use janus::config::models;
use janus::placement::ExpertPlacement;
use janus::routing::gate::{ExpertPopularity, GateSim};
use janus::scheduler::{aebs, baselines};
use janus::util::rng::{split_seed, Rng};

const PANEL: u64 = 13; // fig13's stream id
const N_E: usize = 8;
const BATCH: usize = 64;

struct Cell {
    gate: GateSim,
    placement: ExpertPlacement,
    experts: usize,
}

/// The shared, deterministic panel setup (gate + placement), built from
/// fixed seeds exactly once per scheme — identical across schemes so
/// any output difference comes from the rep streams alone.
fn setup() -> Cell {
    let model = models::deepseek_v2();
    let mut rng = Rng::seed_from_u64(100);
    let gate = GateSim::new(
        model.experts,
        model.top_k,
        &ExpertPopularity::Zipf { s: 0.4 },
        &mut rng,
    );
    let placement =
        ExpertPlacement::contiguous(model.experts, N_E, model.experts.div_ceil(N_E));
    Cell {
        gate,
        placement,
        experts: model.experts,
    }
}

/// One rep of the panel cell: sample a routing batch from `rng`, return
/// (AEBS a_max, EPLB a_max) — the pair Fig 13 averages — plus a batch
/// checksum. The a_max values can saturate to a constant at this batch
/// size; the checksum keeps distinct RNG streams distinguishable so the
/// history-dependence pins cannot go vacuous.
fn rep_value(cell: &Cell, ws: &mut aebs::Workspace, rng: &mut Rng) -> (u32, u32, u64) {
    let b = cell.gate.sample_batch(rng, BATCH);
    let checksum = b
        .expert_token_counts()
        .iter()
        .enumerate()
        .fold(0u64, |acc, (i, &c)| {
            acc.wrapping_mul(0x100000001B3).wrapping_add(i as u64 + c as u64)
        });
    (
        aebs::a_max_only(ws, &b, &cell.placement),
        baselines::token_balanced(&b, &cell.placement).a_max,
        checksum,
    )
}

/// Legacy scheme: one RNG threaded through the rep loop.
fn legacy_sequence(reps: usize, seed: u64) -> Vec<(u32, u32, u64)> {
    let cell = setup();
    let mut ws = aebs::Workspace::new(cell.experts, N_E);
    let mut rng = Rng::seed_from_u64(seed);
    (0..reps).map(|_| rep_value(&cell, &mut ws, &mut rng)).collect()
}

/// Hygienic scheme: every rep derives its own stream from (panel, rep).
fn derived_sequence(reps: usize) -> Vec<(u32, u32, u64)> {
    let cell = setup();
    let mut ws = aebs::Workspace::new(cell.experts, N_E);
    (0..reps)
        .map(|rep| {
            let mut rng = Rng::seed_from_u64(split_seed(PANEL, rep as u64));
            rep_value(&cell, &mut ws, &mut rng)
        })
        .collect()
}

/// One derived rep computed standalone (fresh setup, fresh workspace) —
/// what a sweep cell containing only this rep would compute.
fn derived_rep_alone(rep: usize) -> (u32, u32, u64) {
    let cell = setup();
    let mut ws = aebs::Workspace::new(cell.experts, N_E);
    let mut rng = Rng::seed_from_u64(split_seed(PANEL, rep as u64));
    rep_value(&cell, &mut ws, &mut rng)
}

#[test]
fn legacy_shared_rng_was_history_dependent() {
    let seq = legacy_sequence(8, 101);
    // Rep 2 "alone" under the legacy scheme means restarting the shared
    // RNG — which lands on rep 0's stream, not rep 2's. At least one
    // later rep must differ from the restart value, otherwise the
    // shared stream never mattered and this pin is vacuous.
    let restart = legacy_sequence(1, 101)[0];
    assert_eq!(seq[0], restart, "rep 0 is the restart stream by definition");
    assert!(
        seq[1..].iter().any(|&v| v != restart),
        "shared-RNG reps all equal — pin has no discriminating power"
    );
}

#[test]
fn derived_seeds_make_reps_history_independent() {
    let seq = derived_sequence(8);
    for rep in [0usize, 3, 7] {
        assert_eq!(
            derived_rep_alone(rep),
            seq[rep],
            "rep {rep} standalone ≠ in-sequence: stream leaked across reps"
        );
    }
    // Running a longer sequence does not disturb earlier reps.
    let longer = derived_sequence(16);
    assert_eq!(&longer[..8], &seq[..]);
}

#[test]
fn reseed_is_the_only_delta() {
    // Pin rep 0's derived seed to the legacy seed: the two schemes then
    // perform bit-identical work for that rep, proving the hygiene
    // change altered seed derivation and nothing else in the cell.
    let legacy_first = legacy_sequence(1, split_seed(PANEL, 0))[0];
    let derived_first = derived_sequence(1)[0];
    assert_eq!(legacy_first, derived_first);
}
