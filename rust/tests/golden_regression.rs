//! Golden regressions pinning the perf model + engine:
//!
//! - `fixed_batch.tsv` — seeded fixed-batch runs for Janus and the three
//!   baselines at two batch sizes (TPOT mean/P99, tokens/s/GPU).
//! - `autoscale.tsv` — the arrival-driven autoscale scenario (continuous
//!   batching + bounded admission queue, FIFO admission pinned
//!   explicitly) for all four systems: GPU-hours, duration-weighted
//!   feasible fraction, per-token TPOT percentiles, admission-delay P99,
//!   SLO attainment, and the integer flow counters.
//! - `admission.tsv` — the admission subsystem: one row per (system ×
//!   policy ∈ {fifo, slo, kv}) over a short overload ramp, pinning
//!   per-class TTFT attainment, aggregate token attainment, and the
//!   flow/preemption counters. Policies are enumerated explicitly, so
//!   the snapshot is identical under every `JANUS_ADMISSION` matrix leg.
//! - `flash_crowd.tsv` — the closed-loop scaling acceptance surface: one
//!   row per scaling mode ∈ {reactive, closed} over a flash-crowd trace
//!   on the scripted mock (demand-responsive capacity at a fixed GPU
//!   footprint). The closed row's interactive TTFT attainment strictly
//!   exceeds the reactive row's at bit-identical GPU-hours. Modes are
//!   enumerated explicitly, so the snapshot is identical under every
//!   `JANUS_SCALING` matrix leg — and the other generators pin
//!   `ScalingMode::Reactive` for the same reason.
//! - `faults.tsv` — the fault-plane surface: one row per (system ×
//!   degradation policy ∈ {off, shed, replica}) under a plan exercising
//!   every fault kind. Pins availability, MTTR, narrowed-recovery and
//!   shed counters, and interactive degraded-window attainment; the
//!   fresh rows must show Janus recovering narrowed where the baselines
//!   cannot, and replica strictly beating shed on the scripted mock.
//!   Policies are enumerated explicitly, so the snapshot is identical
//!   under every `JANUS_FAULTS` matrix leg.
//! - `replication.tsv` — the replication-dynamics surface: two
//!   engine-level rows on the scripted mock (identical crash plan,
//!   static-style vs coact-style recovery) plus one crash-action row per
//!   (replication mode × victim instance) on the real JanusSystem at a
//!   pinned 8-instance MoE pool. The fresh rows must show coact beating
//!   static strictly on MTTR and availability, dropping zero experts,
//!   and declaring restoration where static never can. Modes are
//!   enumerated explicitly, so the snapshot is identical under every
//!   `JANUS_REPLICATION` matrix leg.
//!
//! Bootstrap: on a machine without a snapshot (first run after a clone,
//! or after deleting it), the test writes the file and passes with a
//! notice — commit it to pin behavior. With `JANUS_REQUIRE_GOLDEN` set
//! (the CI test step sets it), a missing snapshot FAILS instead of
//! silently re-bootstrapping, so an accidentally deleted baseline cannot
//! erase the drift reference. Re-bless intentionally changed numbers
//! with `JANUS_BLESS=1 cargo test -q golden`.
//!
//! Both snapshot generators drain their (system × batch) grids through
//! `sim::sweep` at the `JANUS_THREADS`-resolved worker count — every
//! cell builds its own system from the fixed ctor seeds, so the rows
//! (and hence the snapshot bytes) are identical to the old serial
//! loops AND identical for any worker count. CI's thread matrix runs
//! these tests at 2 and max workers against the same committed file;
//! `snapshot_generation_is_deterministic` additionally pins threads=1
//! against the resolved count in-process.

use std::path::{Path, PathBuf};

use janus::baselines::{build_eval_system, JanusSystem, ServingSystem, EVAL_SYSTEMS};
use janus::config::hardware::{paper_testbed, HardwareProfile};
use janus::config::models::{self, MoeModel};
use janus::config::serving::{Deployment, Slo};
use janus::placement::ReplicationMode;
use janus::routing::gate::ExpertPopularity;
use janus::scaling::ScalingMode;
use janus::sim::admission::{AdmissionConfig, PolicyKind};
use janus::sim::engine::{self, AutoscaleScenario, FixedBatchScenario};
use janus::sim::sweep;
use janus::testing::MockServingSystem;
use janus::workload::classes::{ClassMix, Priority};
use janus::workload::trace::DiurnalTrace;

const STEPS: usize = 20;
const SEED: u64 = 424242;
const BATCHES: [usize; 2] = [64, 256];
const TOLERANCE: f64 = 1e-9;

fn snapshot_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("tests/golden/{name}"))
}

/// Shared bootstrap/bless/require logic: returns the committed snapshot
/// when a comparison should run, None when the fresh snapshot was just
/// (re-)written.
fn committed_or_bootstrap(path: &Path, fresh: &str) -> Option<String> {
    let bless = std::env::var("JANUS_BLESS").is_ok();
    if bless || !path.exists() {
        // With JANUS_REQUIRE_GOLDEN set (CI), a missing snapshot fails
        // instead of silently re-bootstrapping — re-bootstrapping would
        // erase the drift baseline.
        assert!(
            bless || std::env::var("JANUS_REQUIRE_GOLDEN").is_err(),
            "golden snapshot missing at {} — generate it locally \
             (`cargo test -q golden`) and commit it",
            path.display()
        );
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(path, fresh).unwrap();
        eprintln!(
            "golden: {} snapshot at {} — commit it to pin behavior",
            if bless { "re-blessed" } else { "bootstrapped" },
            path.display()
        );
        return None;
    }
    Some(std::fs::read_to_string(path).unwrap())
}

/// Parse `name \t f64 × n_floats \t u64 × n_ints` rows, skipping comments.
fn parse_rows(snapshot: &str, n_floats: usize, n_ints: usize) -> Vec<(String, Vec<f64>, Vec<u64>)> {
    snapshot
        .lines()
        .filter(|l| !l.trim_start().starts_with('#') && !l.trim().is_empty())
        .map(|l| {
            let f: Vec<&str> = l.split('\t').collect();
            assert_eq!(
                f.len(),
                1 + n_floats + n_ints,
                "malformed snapshot line: {l:?}"
            );
            let floats: Vec<f64> = f[1..1 + n_floats]
                .iter()
                .map(|x| x.parse().expect("float field"))
                .collect();
            let ints: Vec<u64> = f[1 + n_floats..]
                .iter()
                .map(|x| x.parse().expect("int field"))
                .collect();
            (f[0].to_string(), floats, ints)
        })
        .collect()
}

/// Compare two parsed snapshots within `TOLERANCE` on floats, exactly on
/// integer counters.
fn compare_rows(
    committed: &[(String, Vec<f64>, Vec<u64>)],
    current: &[(String, Vec<f64>, Vec<u64>)],
    float_names: &[&str],
    int_names: &[&str],
) {
    assert_eq!(
        committed.len(),
        current.len(),
        "snapshot row count changed — rerun with JANUS_BLESS=1 if intended"
    );
    for ((c_key, c_f, c_i), (n_key, n_f, n_i)) in committed.iter().zip(current.iter()) {
        assert_eq!(c_key, n_key, "snapshot rows reordered");
        for (i, (c, n)) in c_f.iter().zip(n_f.iter()).enumerate() {
            // `nan` fields mark absent per-class samples (a class with no
            // served traffic has no attainment); two absences agree.
            assert!(
                (c.is_nan() && n.is_nan()) || (c - n).abs() <= TOLERANCE,
                "{c_key} {}: committed {c:.17e} vs current {n:.17e} \
                 (drift {:.3e} > {TOLERANCE:.0e}) — simulator behavior changed; \
                 rerun with JANUS_BLESS=1 only if intentional",
                float_names[i],
                (c - n).abs()
            );
        }
        for (i, (c, n)) in c_i.iter().zip(n_i.iter()).enumerate() {
            assert_eq!(
                c, n,
                "{c_key} {}: committed {c} vs current {n} — simulator \
                 behavior changed; rerun with JANUS_BLESS=1 only if intentional",
                int_names[i]
            );
        }
    }
}

/// Build system `which` from the canonical eval ctor seeds
/// (`janus::baselines::build_eval_system`). Each sweep cell builds its
/// own fresh system, exactly as the old per-batch serial loop did, so
/// the rows are byte-identical to the pre-sweep snapshots.
fn build_system(
    which: usize,
    model: &MoeModel,
    hw: &HardwareProfile,
    pop: &ExpertPopularity,
) -> Box<dyn ServingSystem> {
    build_eval_system(which, model.clone(), hw.clone(), pop)
}

const SYSTEMS: usize = EVAL_SYSTEMS;

/// One snapshot row per (system, batch), produced by a parallel sweep
/// whose output order is submission order (worker count not observable).
fn current_fixed_batch_snapshot_at(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 0.4 };
    let slo = Slo::from_ms(200.0);
    let mut out = String::from(
        "# Golden fixed-batch snapshot (DeepSeek-V2, paper testbed, zipf 0.4,\n\
         # SLO 200 ms, steps 20, seed 424242). Regenerate: JANUS_BLESS=1.\n\
         # system/batch\ttpot_mean\ttpot_p99\ttpg\n",
    );
    let cells: Vec<(usize, usize)> = BATCHES
        .iter()
        .flat_map(|&b| (0..SYSTEMS).map(move |s| (b, s)))
        .collect();
    let rows = sweep::sweep(&cells, threads, |_, &(batch, which)| {
        let mut sys = build_system(which, &model, &hw, &pop);
        let r = engine::fixed_batch(
            sys.as_mut(),
            &FixedBatchScenario { batch, slo, steps: STEPS },
            SEED,
        );
        format!(
            "{}/B{}\t{:.17e}\t{:.17e}\t{:.17e}\n",
            r.system, batch, r.tpot_mean, r.tpot_p99, r.tpg
        )
    });
    for row in rows {
        out.push_str(&row);
    }
    out
}

fn current_fixed_batch_snapshot() -> String {
    current_fixed_batch_snapshot_at(sweep::resolve_threads(None))
}

/// One snapshot row per system over the arrival-driven autoscale ramp.
/// The 720 s horizon is deliberately NOT a multiple of the 300 s
/// decision interval, so the truncated final interval's duration
/// weighting is pinned too.
fn current_autoscale_snapshot_at(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 0.4 };
    let trace = DiurnalTrace::ramp(720.0 / 3600.0, 30.0, 1.0, 8.0, 4242);
    let mut scenario = AutoscaleScenario::new(300.0, 64.0, Slo::from_ms(200.0), trace);
    // The pre-admission-subsystem baseline: FIFO + reactive scaling
    // pinned explicitly, so this snapshot stays byte-identical under the
    // JANUS_ADMISSION and JANUS_SCALING CI matrices (the per-policy rows
    // live in admission.tsv, the per-mode rows in flash_crowd.tsv).
    scenario.admission = AdmissionConfig::fifo();
    scenario.scaling = ScalingMode::Reactive;
    let mut out = String::from(
        "# Golden arrival-driven autoscale snapshot (DeepSeek-V2, paper\n\
         # testbed, zipf 0.4, SLO 200 ms, 720 s ramp 1->8 req/s, 64\n\
         # tok/req, 300 s decisions, seed 424242). Regenerate: JANUS_BLESS=1.\n\
         # system\tgpu_hours\tfeasible_fraction\ttpot_mean\ttpot_p99\tadm_p99\tattainment\
\tsteps\tadmitted\tcompleted\trejected\tgenerated\n",
    );
    let cells: Vec<usize> = (0..SYSTEMS).collect();
    let rows = sweep::sweep(&cells, threads, |_, &which| {
        let mut sys = build_system(which, &model, &hw, &pop);
        let r = engine::autoscale(sys.as_mut(), &scenario, SEED).expect("valid scenario");
        format!(
            "{}\t{:.17e}\t{:.17e}\t{:.17e}\t{:.17e}\t{:.17e}\t{:.17e}\t{}\t{}\t{}\t{}\t{}\n",
            r.system,
            r.gpu_hours,
            r.feasible_fraction,
            r.tpot_mean,
            r.tpot_p99,
            r.admission_delay_p99,
            r.slo_attainment,
            r.steps,
            r.admitted_requests,
            r.completed_requests,
            r.rejected_requests,
            r.generated_tokens
        )
    });
    for row in rows {
        out.push_str(&row);
    }
    out
}

fn current_autoscale_snapshot() -> String {
    current_autoscale_snapshot_at(sweep::resolve_threads(None))
}

/// Format an optional per-class attainment: `nan` marks "no samples"
/// (parsed back as `f64::NAN` and matched NaN-to-NaN by `compare_rows`),
/// so an absent class can never be confused with a perfect 1.0.
fn fmt_att(att: Option<f64>) -> String {
    match att {
        Some(v) => format!("{v:.17e}"),
        None => "nan".to_string(),
    }
}

/// One row per (system × admission policy) over a short overload ramp:
/// per-class TTFT attainment, aggregate token attainment, and the flow
/// counters. Policies are enumerated explicitly (never from
/// `JANUS_ADMISSION`), so one committed snapshot pins all three and the
/// CI admission matrix compares against the same bytes.
fn current_admission_snapshot_at(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 0.4 };
    let trace = DiurnalTrace::ramp(240.0 / 3600.0, 30.0, 4.0, 24.0, 777);
    let mut out = String::from(
        "# Golden admission snapshot (DeepSeek-V2, paper testbed, zipf 0.4,\n\
         # SLO 200 ms / TTFT 1 s, 240 s overload ramp 4->24 req/s, 64\n\
         # tok/req, 60 s decisions, seed 424242). One row per system x\n\
         # admission policy. Regenerate: JANUS_BLESS=1.\n\
         # system/policy\tttft_att_interactive\tttft_att_standard\tttft_att_batch\tattainment\
\tadmitted\tcompleted\trejected\tpreempted\tgenerated\n",
    );
    let cells: Vec<(usize, PolicyKind)> = (0..SYSTEMS)
        .flat_map(|s| PolicyKind::ALL.into_iter().map(move |p| (s, p)))
        .collect();
    let rows = sweep::sweep(&cells, threads, |_, &(which, policy)| {
        let mut scenario =
            AutoscaleScenario::new(60.0, 64.0, Slo::from_ms(200.0), trace.clone());
        scenario.admission = AdmissionConfig::with_policy(policy);
        scenario.scaling = ScalingMode::Reactive;
        let mut sys = build_system(which, &model, &hw, &pop);
        let r = engine::autoscale(sys.as_mut(), &scenario, SEED).expect("valid scenario");
        format!(
            "{}/{}\t{}\t{}\t{}\t{:.17e}\t{}\t{}\t{}\t{}\t{}\n",
            r.system,
            policy.name(),
            fmt_att(r.per_class[0].ttft_attainment()),
            fmt_att(r.per_class[1].ttft_attainment()),
            fmt_att(r.per_class[2].ttft_attainment()),
            r.slo_attainment,
            r.admitted_requests,
            r.completed_requests,
            r.rejected_requests,
            r.preemptions,
            r.generated_tokens
        )
    });
    for row in rows {
        out.push_str(&row);
    }
    out
}

fn current_admission_snapshot() -> String {
    current_admission_snapshot_at(sweep::resolve_threads(None))
}

/// One row per scaling mode over a flash-crowd trace on the scripted
/// mock (demand-responsive batch capacity at a fixed 4-GPU footprint —
/// both rows accrue bit-identical GPU-hours). Modes are enumerated
/// explicitly (never from `JANUS_SCALING`), so one committed snapshot
/// pins both and the CI scaling matrix compares against the same bytes.
/// The scenario mirrors `tests/closed_loop.rs`: the burst ends before
/// the second decision, so only the closed loop sees the backlog.
fn current_flash_crowd_snapshot_at(threads: usize) -> String {
    let trace = DiurnalTrace::flash_crowd(240.0 / 3600.0, 10.0, 1.0, 60.0, 10.0, 50.0, 19);
    let mut out = String::from(
        "# Golden flash-crowd snapshot (scripted mock with demand-responsive\n\
         # capacity at fixed 4 GPUs, 1 req/s base + 60 req/s burst over\n\
         # [10,50) s, 8 tok/req, 60 s decisions, TTFT 1 s, seed 424242).\n\
         # One row per scaling mode. Regenerate: JANUS_BLESS=1.\n\
         # mode\tgpu_hours\tttft_att_interactive\tttft_p99\
\tsteps\tadmitted\tcompleted\trejected\tgenerated\n",
    );
    let modes = [ScalingMode::Reactive, ScalingMode::Closed];
    let rows = sweep::sweep(&modes, threads, |_, &mode| {
        let mut scenario =
            AutoscaleScenario::new(60.0, 8.0, Slo::from_ms(200.0), trace.clone());
        scenario.admission = AdmissionConfig::fifo();
        scenario.admission.class_mix = ClassMix::single(Priority::Interactive);
        scenario.scaling = mode;
        let mut sys = MockServingSystem::new(4, 8, 0.05).with_demand_response(20.0, 64);
        let r = engine::autoscale(&mut sys, &scenario, SEED).expect("valid scenario");
        format!(
            "{}\t{:.17e}\t{}\t{:.17e}\t{}\t{}\t{}\t{}\t{}\n",
            mode.name(),
            r.gpu_hours,
            fmt_att(r.per_class[Priority::Interactive.rank()].ttft_attainment()),
            r.ttft_p99,
            r.steps,
            r.admitted_requests,
            r.completed_requests,
            r.rejected_requests,
            r.generated_tokens
        )
    });
    for row in rows {
        out.push_str(&row);
    }
    out
}

fn current_flash_crowd_snapshot() -> String {
    current_flash_crowd_snapshot_at(sweep::resolve_threads(None))
}

/// One row per (system × degradation policy) under a fault plan that
/// exercises every fault kind: an instance crash (narrowed for Janus,
/// whole-pool for the baselines), a straggler window, a transient
/// dispatch/combine window, and an attention-host loss on the recompute
/// path. Policies are enumerated explicitly (never from `JANUS_FAULTS`),
/// so one committed snapshot pins all three and the CI faults matrix
/// compares against the same bytes. The fifth "system" is the scripted
/// mock (constant 10 ms steps), whose shed-vs-replica rows carry the
/// degradation acceptance invariant: replica must strictly beat shed on
/// interactive degraded-window attainment.
fn current_faults_snapshot_at(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 0.4 };
    let mut out = String::from(
        "# Golden fault-plane snapshot (DeepSeek-V2, paper testbed, zipf 0.4,\n\
         # SLO 200 ms, 180 s horizon at 4 req/s x 32 tok/req, seed 424242).\n\
         # Plan: instance crash @30s/60s, straggler x2 @50s/40s, transient\n\
         # p=0.5 @100s/20s, attention-host loss (recompute) @140s/20s.\n\
         # One row per system x degradation policy. Regenerate: JANUS_BLESS=1.\n\
         # system/policy\tavailability\tmttr_mean\tdegr_att_interactive\ttpot_mean\
\tsteps\tadmitted\tcompleted\tpreempted\tshed\tnarrowed\trecompute_tokens\n",
    );
    let cells: Vec<(usize, janus::sim::faults::DegradationPolicy)> = (0..SYSTEMS + 1)
        .flat_map(|s| {
            janus::sim::faults::DegradationPolicy::ALL
                .into_iter()
                .map(move |p| (s, p))
        })
        .collect();
    let rows = sweep::sweep(&cells, threads, |_, &(which, policy)| {
        let plan = janus::sim::faults::FaultPlan::new()
            .with_instance_crash(30.0, 60.0, 0)
            .with_straggler(50.0, 40.0, 2.0)
            .with_transient_comm(100.0, 20.0, 0.5)
            .with_attention_host_loss(140.0, 20.0, 1, false)
            .with_policy(policy);
        let mut scenario = janus::sim::engine::FailureScenario::new(
            Slo::from_ms(200.0),
            4.0,
            32.0,
            180.0,
        )
        .with_faults(plan);
        scenario.admission = AdmissionConfig::fifo();
        scenario.scaling = ScalingMode::Reactive;
        let mut sys: Box<dyn ServingSystem> = if which < SYSTEMS {
            build_system(which, &model, &hw, &pop)
        } else {
            Box::new(MockServingSystem::new(4, 64, 0.01))
        };
        let r = engine::failure_injection(sys.as_mut(), &scenario, SEED)
            .expect("valid scenario");
        format!(
            "{}/{}\t{:.17e}\t{:.17e}\t{}\t{:.17e}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
            r.system,
            policy.name(),
            r.availability,
            r.mttr_mean,
            fmt_att(r.per_class[Priority::Interactive.rank()].degraded_token_attainment()),
            r.tpot.mean(),
            r.steps,
            r.admitted_requests,
            r.completed_requests,
            r.preemptions,
            r.shed_requests,
            r.faults.narrowed_events(),
            r.faults.recompute_tokens
        )
    });
    for row in rows {
        out.push_str(&row);
    }
    out
}

fn current_faults_snapshot() -> String {
    current_faults_snapshot_at(sweep::resolve_threads(None))
}

/// The replication-dynamics surface. Two engine-level rows run the same
/// seeded crash plan on the scripted mock with a static-style recovery
/// (zero free slots, dropped experts, no restoration) vs a coact-style
/// one (every expert re-seated, restored 2 s after the crash). Sixteen
/// crash-action rows crash each of the 8 MoE instances of a real
/// JanusSystem pinned to `Deployment::new(4, 8)` — the regime where a
/// static placement saturates every slot (216 < 2 × 160) while the
/// coact placement keeps headroom — under both replication modes.
/// Engine-only columns (`availability`, `mttr_mean`) are `nan` on the
/// action rows; `restored` counts early repairs on engine rows and the
/// restored-declaration flag on action rows. Modes are enumerated
/// explicitly (never from `JANUS_REPLICATION`), so one committed
/// snapshot pins both and the CI replication matrix compares against
/// the same bytes.
fn current_replication_snapshot_at(threads: usize) -> String {
    use janus::sim::faults::{DegradationPolicy, FaultPlan};
    let mut out = String::from(
        "# Golden replication snapshot. Engine rows: scripted mock, crash\n\
         # @30s/60s, replica policy, 180 s horizon at 2 req/s x 32 tok/req,\n\
         # seed 424242. Action rows: JanusSystem (DeepSeek-V2, paper\n\
         # testbed, zipf 1.2, ctor seed 47) pinned to 4 attn + 8 MoE\n\
         # instances, one crash per victim per mode. Regenerate:\n\
         # JANUS_BLESS=1.\n\
         # key\tavailability\tmttr_mean\trepair_secs\trestored\tdropped\tre_replicated\n",
    );
    #[derive(Clone, Copy)]
    enum Cell {
        Engine(&'static str),
        Crash(ReplicationMode, u32),
    }
    let mut cells: Vec<Cell> = vec![Cell::Engine("static"), Cell::Engine("coact")];
    for mode in ReplicationMode::ALL {
        for victim in 0..8u32 {
            cells.push(Cell::Crash(mode, victim));
        }
    }
    let rows = sweep::sweep(&cells, threads, |_, &cell| match cell {
        Cell::Engine(style) => {
            let plan = FaultPlan::new()
                .with_instance_crash(30.0, 60.0, 0)
                .with_policy(DegradationPolicy::Replica);
            let mut scenario = janus::sim::engine::FailureScenario::new(
                Slo::from_ms(200.0),
                2.0,
                32.0,
                180.0,
            )
            .with_faults(plan);
            scenario.admission = AdmissionConfig::fifo();
            scenario.scaling = ScalingMode::Reactive;
            let base = MockServingSystem::new(4, 64, 0.01);
            let mut sys = if style == "static" {
                base.with_narrowed_crash(0, 0.0).with_crash_dropped(3)
            } else {
                base.with_narrowed_crash(5, 0.4).with_restored_secs(2.0)
            };
            let r = engine::failure_injection(&mut sys, &scenario, SEED)
                .expect("valid scenario");
            let ev = &r.faults.events[0];
            format!(
                "mock-{style}/engine\t{:.17e}\t{:.17e}\t{:.17e}\t{}\t{}\t{}\n",
                r.availability,
                r.mttr_mean,
                ev.transfer_secs + r.faults.background_transfer_secs,
                r.faults.early_repairs,
                ev.dropped_experts,
                r.faults.re_replicated_experts,
            )
        }
        Cell::Crash(mode, victim) => {
            let mut sys = JanusSystem::build_with_replication(
                models::deepseek_v2(),
                paper_testbed(),
                &ExpertPopularity::Zipf { s: 1.2 },
                16,
                47,
                mode,
            );
            sys.deploy(Deployment::new(4, 8));
            let a = sys.crash_instance(
                victim,
                DegradationPolicy::Replica,
                2.0,
                Slo::from_ms(200.0),
            );
            format!(
                "{}/v{victim}\tnan\tnan\t{:.17e}\t{}\t{}\t{}\n",
                mode.name(),
                a.transfer_secs + a.background_secs,
                u64::from(a.restored_secs.is_some()),
                a.dropped_experts,
                a.re_replicated_experts,
            )
        }
    });
    for row in rows {
        out.push_str(&row);
    }
    out
}

fn current_replication_snapshot() -> String {
    current_replication_snapshot_at(sweep::resolve_threads(None))
}

#[test]
fn fixed_batch_metrics_match_snapshot() {
    let path = snapshot_path("fixed_batch.tsv");
    let fresh = current_fixed_batch_snapshot();
    let Some(committed) = committed_or_bootstrap(&path, &fresh) else {
        return;
    };
    compare_rows(
        &parse_rows(&committed, 3, 0),
        &parse_rows(&fresh, 3, 0),
        &["tpot_mean", "tpot_p99", "tpg"],
        &[],
    );
}

#[test]
fn autoscale_metrics_match_snapshot() {
    let path = snapshot_path("autoscale.tsv");
    let fresh = current_autoscale_snapshot();
    let Some(committed) = committed_or_bootstrap(&path, &fresh) else {
        return;
    };
    compare_rows(
        &parse_rows(&committed, 6, 5),
        &parse_rows(&fresh, 6, 5),
        &[
            "gpu_hours",
            "feasible_fraction",
            "tpot_mean",
            "tpot_p99",
            "adm_p99",
            "attainment",
        ],
        &["steps", "admitted", "completed", "rejected", "generated"],
    );
}

#[test]
fn admission_policies_match_snapshot() {
    let path = snapshot_path("admission.tsv");
    let fresh = current_admission_snapshot();
    let Some(committed) = committed_or_bootstrap(&path, &fresh) else {
        return;
    };
    compare_rows(
        &parse_rows(&committed, 4, 5),
        &parse_rows(&fresh, 4, 5),
        &[
            "ttft_att_interactive",
            "ttft_att_standard",
            "ttft_att_batch",
            "attainment",
        ],
        &["admitted", "completed", "rejected", "preempted", "generated"],
    );
}

#[test]
fn flash_crowd_scaling_matches_snapshot() {
    let path = snapshot_path("flash_crowd.tsv");
    let fresh = current_flash_crowd_snapshot();
    // Acceptance invariant, checked on the fresh rows themselves (not
    // just against committed bytes): closed-loop scaling strictly beats
    // reactive on interactive TTFT attainment at bit-identical
    // GPU-hours.
    let rows = parse_rows(&fresh, 3, 5);
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].0, "reactive");
    assert_eq!(rows[1].0, "closed");
    assert!(
        rows[1].1[1] > rows[0].1[1],
        "closed interactive TTFT attainment {} must strictly exceed reactive's {}",
        rows[1].1[1],
        rows[0].1[1]
    );
    assert_eq!(
        rows[0].1[0].to_bits(),
        rows[1].1[0].to_bits(),
        "GPU-hours must match bit-for-bit at a fixed pool"
    );
    let Some(committed) = committed_or_bootstrap(&path, &fresh) else {
        return;
    };
    compare_rows(
        &parse_rows(&committed, 3, 5),
        &parse_rows(&fresh, 3, 5),
        &["gpu_hours", "ttft_att_interactive", "ttft_p99"],
        &["steps", "admitted", "completed", "rejected", "generated"],
    );
}

#[test]
fn fault_plane_matches_snapshot() {
    let path = snapshot_path("faults.tsv");
    let fresh = current_faults_snapshot();
    let rows = parse_rows(&fresh, 4, 7);
    assert_eq!(rows.len(), (SYSTEMS + 1) * 3, "5 systems x 3 policies");
    // Acceptance invariants, checked on the fresh rows themselves (not
    // just against committed bytes):
    // 1. Janus recovers the instance crash narrowed; the monolithic
    //    baselines never do.
    for (key, _, ints) in &rows {
        let narrowed = ints[5];
        if key.starts_with("janus/") {
            assert!(narrowed > 0, "{key}: Janus must repair narrowed");
        }
        if key.starts_with("sglang/") {
            assert_eq!(narrowed, 0, "{key}: no per-instance placement");
        }
    }
    // 2. On the scripted mock (steps always meet the target, so the only
    //    attainment loss is shed tokens): replica strictly beats shed on
    //    interactive degraded-window attainment, and only shed sheds.
    let find = |key: &str| {
        rows.iter()
            .find(|(k, _, _)| k == key)
            .unwrap_or_else(|| panic!("missing row {key}"))
    };
    let shed = find("mock/shed");
    let replica = find("mock/replica");
    assert!(shed.2[4] > 0, "shed policy never shed an arrival");
    assert_eq!(replica.2[4], 0, "replica policy must not shed");
    assert!(
        replica.1[2] > shed.1[2],
        "replica interactive degraded attainment {} must strictly exceed shed's {}",
        replica.1[2],
        shed.1[2]
    );
    let Some(committed) = committed_or_bootstrap(&path, &fresh) else {
        return;
    };
    compare_rows(
        &parse_rows(&committed, 4, 7),
        &parse_rows(&fresh, 4, 7),
        &[
            "availability",
            "mttr_mean",
            "degr_att_interactive",
            "tpot_mean",
        ],
        &[
            "steps",
            "admitted",
            "completed",
            "preempted",
            "shed",
            "narrowed",
            "recompute_tokens",
        ],
    );
}

#[test]
fn replication_dynamics_match_snapshot() {
    let path = snapshot_path("replication.tsv");
    let fresh = current_replication_snapshot();
    let rows = parse_rows(&fresh, 3, 3);
    assert_eq!(rows.len(), 2 + 2 * 8, "2 engine rows + 2 modes x 8 victims");
    // Acceptance invariants, checked on the fresh rows themselves (not
    // just against committed bytes):
    // 1. Engine level: under the identical crash plan and replica
    //    policy, coact-style recovery strictly beats static-style on
    //    both MTTR and availability, and only coact closes the fault
    //    window early.
    let find = |key: &str| {
        rows.iter()
            .find(|(k, _, _)| k == key)
            .unwrap_or_else(|| panic!("missing row {key}"))
    };
    let st = find("mock-static/engine");
    let co = find("mock-coact/engine");
    assert!(
        co.1[1] < st.1[1],
        "coact mttr_mean {} must be strictly below static's {}",
        co.1[1],
        st.1[1]
    );
    assert!(
        co.1[0] > st.1[0],
        "coact availability {} must strictly exceed static's {}",
        co.1[0],
        st.1[0]
    );
    assert!(co.2[0] >= 1, "coact must repair early");
    assert_eq!(st.2[0], 0, "static must never repair early");
    // 2. Crash-action level: a static placement drops at least one
    //    sole-replica expert somewhere and never declares restoration or
    //    re-replicates; the coact placement recovers EVERY victim with
    //    zero drops and a restored declaration.
    let mut static_drops = 0u64;
    for (key, floats, ints) in &rows {
        if let Some(v) = key.strip_prefix("static/v") {
            assert!(v.parse::<u32>().is_ok(), "malformed key {key}");
            static_drops += ints[1];
            assert_eq!(ints[0], 0, "{key}: static never declares restoration");
            assert_eq!(ints[2], 0, "{key}: static never re-replicates");
            assert_eq!(floats[2], 0.0, "{key}: static repairs move nothing");
        }
        if key.starts_with("coact/v") {
            assert_eq!(ints[1], 0, "{key}: coact must not drop experts");
            assert_eq!(ints[0], 1, "{key}: coact must declare restoration");
        }
    }
    assert!(static_drops > 0, "static crashes must drop experts somewhere");
    assert!(
        rows.iter()
            .any(|(k, f, _)| k.starts_with("coact/v") && f[2] > 0.0),
        "at least one coact repair must model transfer work"
    );
    assert!(
        rows.iter()
            .any(|(k, _, i)| k.starts_with("coact/v") && i[2] > 0),
        "at least one coact repair must re-replicate onto survivors"
    );
    let Some(committed) = committed_or_bootstrap(&path, &fresh) else {
        return;
    };
    compare_rows(
        &parse_rows(&committed, 3, 3),
        &parse_rows(&fresh, 3, 3),
        &["availability", "mttr_mean", "repair_secs"],
        &["restored", "dropped", "re_replicated"],
    );
}

/// The snapshot generators are bit-deterministic — the precondition for
/// the golden files being meaningful across machines and runs — and the
/// sweep's worker count is not an observable: the serial (threads=1)
/// bytes equal the resolved-parallel bytes.
#[test]
fn snapshot_generation_is_deterministic() {
    assert_eq!(current_fixed_batch_snapshot(), current_fixed_batch_snapshot());
    assert_eq!(current_autoscale_snapshot(), current_autoscale_snapshot());
    assert_eq!(current_admission_snapshot(), current_admission_snapshot());
    assert_eq!(current_flash_crowd_snapshot(), current_flash_crowd_snapshot());
    assert_eq!(current_faults_snapshot(), current_faults_snapshot());
    assert_eq!(current_faults_snapshot_at(1), current_faults_snapshot());
    assert_eq!(current_replication_snapshot(), current_replication_snapshot());
    assert_eq!(
        current_replication_snapshot_at(1),
        current_replication_snapshot()
    );
    assert_eq!(
        current_fixed_batch_snapshot_at(1),
        current_fixed_batch_snapshot()
    );
    assert_eq!(current_autoscale_snapshot_at(1), current_autoscale_snapshot());
    assert_eq!(current_admission_snapshot_at(1), current_admission_snapshot());
    assert_eq!(
        current_flash_crowd_snapshot_at(1),
        current_flash_crowd_snapshot()
    );
}
