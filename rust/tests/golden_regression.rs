//! Golden regression pinning the perf model + engine: seeded fixed-batch
//! runs for Janus and the three baselines at two batch sizes, asserting
//! TPOT mean/P99 and tokens/s/GPU against a committed snapshot to 1e-9.
//!
//! Bootstrap: on a machine without the snapshot (first run after a
//! clone, or after deleting it), the test writes
//! `tests/golden/fixed_batch.tsv` and passes with a notice — commit the
//! file to pin behavior. Re-bless intentionally changed numbers with
//! `JANUS_BLESS=1 cargo test -q golden`. Any unintentional drift in the
//! perf model, schedulers, placement, or engine then fails here before
//! it contaminates downstream figures.

use std::fmt::Write as _;
use std::path::PathBuf;

use janus::baselines::{JanusSystem, MegaScaleInfer, ServingSystem, SgLang, XDeepServe};
use janus::config::hardware::paper_testbed;
use janus::config::models;
use janus::config::serving::Slo;
use janus::sim::engine::{self, FixedBatchScenario};

const STEPS: usize = 20;
const SEED: u64 = 424242;
const BATCHES: [usize; 2] = [64, 256];
const TOLERANCE: f64 = 1e-9;

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/fixed_batch.tsv")
}

/// One snapshot row per (system, batch).
fn current_snapshot() -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let slo = Slo::from_ms(200.0);
    let mut out = String::from(
        "# Golden fixed-batch snapshot (DeepSeek-V2, paper testbed, zipf 0.4,\n\
         # SLO 200 ms, steps 20, seed 424242). Regenerate: JANUS_BLESS=1.\n\
         # system\tbatch\ttpot_mean\ttpot_p99\ttpg\n",
    );
    for &batch in &BATCHES {
        let mut janus = JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 42);
        let mut sgl = SgLang::build(model.clone(), hw.clone(), &pop, 43);
        let mut msi = MegaScaleInfer::build(model.clone(), hw.clone(), &pop, 16, 44);
        let mut xds = XDeepServe::build(model.clone(), hw.clone(), &pop, 32, 45);
        let systems: Vec<&mut dyn ServingSystem> =
            vec![&mut janus, &mut sgl, &mut msi, &mut xds];
        for sys in systems {
            let r = engine::fixed_batch(
                sys,
                &FixedBatchScenario { batch, slo, steps: STEPS },
                SEED,
            );
            writeln!(
                out,
                "{}\t{}\t{:.17e}\t{:.17e}\t{:.17e}",
                r.system, batch, r.tpot_mean, r.tpot_p99, r.tpg
            )
            .unwrap();
        }
    }
    out
}

fn parse(snapshot: &str) -> Vec<(String, usize, [f64; 3])> {
    snapshot
        .lines()
        .filter(|l| !l.trim_start().starts_with('#') && !l.trim().is_empty())
        .map(|l| {
            let f: Vec<&str> = l.split('\t').collect();
            assert_eq!(f.len(), 5, "malformed snapshot line: {l:?}");
            (
                f[0].to_string(),
                f[1].parse().expect("batch"),
                [
                    f[2].parse().expect("tpot_mean"),
                    f[3].parse().expect("tpot_p99"),
                    f[4].parse().expect("tpg"),
                ],
            )
        })
        .collect()
}

#[test]
fn fixed_batch_metrics_match_snapshot() {
    let path = snapshot_path();
    let fresh = current_snapshot();
    let bless = std::env::var("JANUS_BLESS").is_ok();
    if bless || !path.exists() {
        // Once the snapshot is committed, set JANUS_REQUIRE_GOLDEN in CI
        // so a missing/deleted snapshot fails instead of silently
        // re-bootstrapping (which would erase the drift baseline).
        assert!(
            bless || std::env::var("JANUS_REQUIRE_GOLDEN").is_err(),
            "golden snapshot missing at {} — generate it locally \
             (`cargo test -q golden`) and commit it",
            path.display()
        );
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &fresh).unwrap();
        eprintln!(
            "golden: {} snapshot at {} — commit it to pin behavior",
            if bless { "re-blessed" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let committed = parse(&std::fs::read_to_string(&path).unwrap());
    let current = parse(&fresh);
    assert_eq!(
        committed.len(),
        current.len(),
        "snapshot row count changed — rerun with JANUS_BLESS=1 if intended"
    );
    let metric_names = ["tpot_mean", "tpot_p99", "tpg"];
    for ((c_sys, c_batch, c_vals), (n_sys, n_batch, n_vals)) in
        committed.iter().zip(current.iter())
    {
        assert_eq!((c_sys, c_batch), (n_sys, n_batch), "snapshot rows reordered");
        for (i, (c, n)) in c_vals.iter().zip(n_vals.iter()).enumerate() {
            assert!(
                (c - n).abs() <= TOLERANCE,
                "{c_sys} B={c_batch} {}: committed {c:.17e} vs current {n:.17e} \
                 (drift {:.3e} > {TOLERANCE:.0e}) — perf-model behavior changed; \
                 rerun with JANUS_BLESS=1 only if intentional",
                metric_names[i],
                (c - n).abs()
            );
        }
    }
}

/// The snapshot generator itself is bit-deterministic — the precondition
/// for the golden file being meaningful across machines and runs.
#[test]
fn snapshot_generation_is_deterministic() {
    assert_eq!(current_snapshot(), current_snapshot());
}
