//! Cross-module integration tests: the full policy pipeline (trace →
//! placement → scheduling → performance model → scaling), system-level
//! invariants, and failure injection.

use janus::baselines::{JanusSystem, MegaScaleInfer, ServingSystem, SgLang, XDeepServe};
use janus::config::hardware::paper_testbed;
use janus::config::models;
use janus::config::serving::{self, SchedulerKind, Slo};
use janus::placement::{allocate_replicas, place_replicas, ExpertPlacement};
use janus::routing::coactivation::CoactivationStats;
use janus::routing::gate::{ExpertPopularity, GateSim};
use janus::routing::trace::ActivationTrace;
use janus::scaling::{amax_bound, AmaxTable, Scaler};
use janus::scheduler::{self, aebs};
use janus::sim::autoscale_sim::AutoscaleSim;
use janus::sim::decode_sim::evaluate_fixed_batch;
use janus::sim::engine::{
    self, AutoscaleScenario, FailureScenario, FixedBatchScenario, Scenario, ScenarioOutcome,
};
use janus::testing::prop;
use janus::util::rng::Rng;
use janus::workload::trace::DiurnalTrace;

/// The full §3.5 pipeline end to end: synthetic trace → replica counts →
/// Algorithm 3 placement → AEBS scheduling → a_max beats every baseline
/// scheduler on average.
#[test]
fn pipeline_trace_to_scheduling_beats_baselines() {
    let mut rng = Rng::seed_from_u64(1);
    let model = models::deepseek_v2();
    let gate = GateSim::new(
        model.experts,
        model.top_k,
        &ExpertPopularity::Zipf { s: 0.6 },
        &mut rng,
    );
    let mut trace = ActivationTrace::new(model.experts, model.top_k, 8192);
    trace.record_batch(&gate.sample_batch(&mut rng, 8192));
    let counts = trace.expert_counts();
    let coact = CoactivationStats::from_trace(&trace, 64);
    let (n_e, cap) = (12, 27);
    let replicas = allocate_replicas(&counts, n_e, cap);
    let placement = place_replicas(&replicas, &counts, &coact, n_e, cap);
    placement.validate().unwrap();

    let mut ws = aebs::Workspace::new(model.experts, n_e);
    let (mut a_aebs, mut a_tb, mut a_rand) = (0u64, 0u64, 0u64);
    for _ in 0..40 {
        let b = gate.sample_batch(&mut rng, 256);
        a_aebs += aebs::a_max_only(&mut ws, &b, &placement) as u64;
        a_tb += scheduler::baselines::token_balanced(&b, &placement).a_max as u64;
        a_rand += scheduler::baselines::random(&b, &placement, &mut rng).a_max as u64;
    }
    assert!(a_aebs < a_tb, "AEBS {a_aebs} vs token-balanced {a_tb}");
    assert!(a_aebs < a_rand, "AEBS {a_aebs} vs random {a_rand}");
}

/// Property: over random workloads and MoE-side sizes, the analytic bound
/// (Eq. 5) dominates the Monte-Carlo estimate at every grid point — the
/// Fig 17 invariant, exercised across model shapes.
#[test]
fn bound_dominates_mc_across_shapes() {
    prop::check("bound >= MC", 10, |rng| {
        let experts = 64 + rng.usize_below(3) * 48; // 64/112/160
        let top_k = 2 + rng.usize_below(5);
        let skew = rng.f64_range(0.0, 1.0);
        let gate = GateSim::new(experts, top_k, &ExpertPopularity::Zipf { s: skew }, rng);
        let mut trace = ActivationTrace::new(experts, top_k, 4096);
        trace.record_batch(&gate.sample_batch(rng, 4096));
        let capacity = experts / 6 + 2;
        let n_e = experts.div_ceil(capacity) + rng.usize_below(4);
        let grid = [8usize, 64, 256];
        let table = AmaxTable::build(
            &trace,
            &[n_e],
            &grid,
            capacity,
            SchedulerKind::Aebs,
            6,
            rng,
        );
        let probs = gate.activation_probs();
        let placement = table.placement_for(n_e).unwrap();
        for &b in &grid {
            let mc = table.lookup(n_e, b as f64);
            let bd = amax_bound(&probs, placement, b as f64);
            assert!(bd + 1e-9 >= mc, "n_e={n_e} B={b}: bound {bd} < MC {mc}");
        }
    });
}

/// All four systems produce valid, SLO-meeting-or-flagged evaluations at
/// every batch size, and Janus never violates.
#[test]
fn four_system_comparison_is_well_formed() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Uniform;
    let slo = Slo::from_ms(200.0);
    let mut janus = JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 1);
    let mut sgl = SgLang::build(model.clone(), hw.clone(), &pop, 2);
    let mut msi = MegaScaleInfer::build(model.clone(), hw.clone(), &pop, 16, 3);
    let mut xds = XDeepServe::build(model, hw, &pop, 32, 4);
    for batch in [64usize, 256, 1024] {
        let systems: Vec<&mut dyn ServingSystem> =
            vec![&mut janus, &mut sgl, &mut msi, &mut xds];
        for sys in systems {
            let r = evaluate_fixed_batch(sys, batch, slo, 10, 5);
            assert!(r.tpot_mean > 0.0, "{}: zero TPOT", r.system);
            assert!(r.gpus > 0, "{}: no GPUs", r.system);
            assert!(r.tpot_p99 >= r.tpot_mean * 0.999);
            if r.system == "Janus" {
                assert!(r.feasible, "Janus must find a config at B={batch}");
                assert!(
                    r.slo_attainment > 0.99,
                    "Janus attainment {} at B={batch}",
                    r.slo_attainment
                );
            }
        }
    }
}

/// Autoscaling over a compressed demand ramp: Janus tracks demand with
/// finer steps than SGLang's tiers, never exceeds the pool, and the
/// arrival-driven decode loop reports live latency metrics.
#[test]
fn autoscale_tracks_demand_within_pool() {
    // 300 s trough-to-peak ramp (256 → 20480 tok/s at 256 tokens/req):
    // wide enough to force scale-up, short enough for per-token decode.
    let trace = DiurnalTrace::ramp(300.0 / 3600.0, 30.0, 1.0, 80.0, 9);
    let sim = AutoscaleSim::new(75.0, 256.0, Slo::from_ms(200.0)).with_seed(9);
    let hw = janus::config::hardware::autoscale_pool();
    let mut janus = JanusSystem::build(
        models::deepseek_v2(),
        hw,
        &ExpertPopularity::Uniform,
        32,
        9,
    );
    let r = sim.run(&mut janus, &trace).expect("valid scenario");
    assert!(r.max_gpus <= 64);
    assert!(r.min_gpus >= 7);
    // Distinct GPU counts across intervals — fine-grained steps, not tiers.
    let mut counts: Vec<usize> = r.intervals.iter().map(|i| i.gpus).collect();
    counts.sort_unstable();
    counts.dedup();
    assert!(counts.len() >= 2, "Janus should use multiple configurations");
    // The decode loop is live: admission + per-token latency measured.
    assert!(r.steps > 0 && r.admitted_requests > 0 && r.completed_requests > 0);
    assert!(r.tpot_p99 >= r.tpot_p50 && r.tpot_p50 > 0.0);
    assert!(r.queue_depth_max >= 1);
}

/// Failure injection: scaler behaviour at impossible demands, degenerate
/// SLOs, and capacity edges.
#[test]
fn scaler_failure_modes() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let capacity = serving::default_capacity(&model, &hw);
    let mut rng = Rng::seed_from_u64(10);
    let gate = GateSim::new(model.experts, model.top_k, &ExpertPopularity::Uniform, &mut rng);
    let mut trace = ActivationTrace::new(model.experts, model.top_k, 4096);
    trace.record_batch(&gate.sample_batch(&mut rng, 4096));
    let n_e_min = model.experts.div_ceil(capacity);
    let n_e_values: Vec<usize> = (n_e_min..=12).collect();
    let amax = AmaxTable::build(
        &trace,
        &n_e_values,
        &AmaxTable::default_grid(2048),
        capacity,
        SchedulerKind::Aebs,
        4,
        &mut rng,
    );
    let scaler = Scaler::new(model, hw, amax, 12);
    // Impossible demand.
    assert!(scaler.optimize(1e12, Slo::from_ms(200.0), 512.0).is_none());
    // Impossible SLO (1 µs).
    assert!(scaler
        .optimize(1000.0, Slo { tpot: 1e-6 }, 512.0)
        .is_none());
    // Tiny demand still seats all experts (n_e ≥ n_e_min).
    let plan = scaler.optimize(1.0, Slo::from_ms(500.0), 512.0).unwrap();
    assert!(plan.deployment.n_moe >= scaler.n_e_min());
    // Very long contexts shrink feasibility but must not panic.
    let _ = scaler.optimize(1000.0, Slo::from_ms(200.0), 100_000.0);
}

/// Placement stress: random replica-count vectors always yield valid
/// layouts through Algorithm 3, even at exact-fit capacity.
#[test]
fn placement_fuzz_always_valid() {
    prop::check("algorithm3 validity", 25, |rng| {
        let experts = 16 + rng.usize_below(64);
        let n_e = 4 + rng.usize_below(8);
        let capacity = experts.div_ceil(n_e) + rng.usize_below(3);
        let slots = n_e * capacity;
        let counts: Vec<u64> = (0..experts).map(|_| rng.next_u64() % 1000).collect();
        if slots < experts {
            return;
        }
        let replicas = allocate_replicas(&counts, n_e, capacity);
        let gate = GateSim::new(experts, 2.min(experts), &ExpertPopularity::Uniform, rng);
        let mut trace = ActivationTrace::new(experts, 2.min(experts), 1024);
        trace.record_batch(&gate.sample_batch(rng, 1024));
        let coact = CoactivationStats::from_trace(&trace, 32);
        let placement = place_replicas(&replicas, &counts, &coact, n_e, capacity);
        placement.validate().unwrap();
        for e in 0..experts {
            assert_eq!(placement.replica_count(e as u16), replicas[e]);
        }
    });
}

/// Determinism: the whole evaluation pipeline is reproducible bit-for-bit
/// from the seed (the property the synchronization-free AEBS requires and
/// the experiments rely on).
#[test]
fn end_to_end_determinism() {
    let run = || {
        let mut sys = JanusSystem::build(
            models::deepseek_v2(),
            paper_testbed(),
            &ExpertPopularity::Zipf { s: 0.4 },
            16,
            123,
        );
        let r = evaluate_fixed_batch(&mut sys, 256, Slo::from_ms(200.0), 20, 99);
        (r.config_label, r.tpot_mean.to_bits(), r.tpg.to_bits())
    };
    assert_eq!(run(), run());
}

/// The unified engine runs all three scenarios (fixed-batch decode,
/// diurnal autoscale, failure injection) for all four systems from one
/// API — the acceptance criterion of the sim::engine refactor.
#[test]
fn engine_runs_all_scenarios_for_all_systems() {
    let model = models::deepseek_v2();
    let hw = janus::config::hardware::autoscale_pool();
    let pop = ExpertPopularity::Uniform;
    let slo = Slo::from_ms(200.0);
    let scenarios = [
        Scenario::FixedBatch(FixedBatchScenario {
            batch: 128,
            slo,
            steps: 8,
        }),
        Scenario::Autoscale(AutoscaleScenario::new(
            150.0,
            32.0,
            slo,
            DiurnalTrace::ramp(600.0 / 3600.0, 30.0, 1.0, 8.0, 12),
        )),
        Scenario::FailureInjection(
            FailureScenario::new(slo, 2.0, 32.0, 180.0).with_failure(60.0, 8, 60.0),
        ),
    ];
    let mut janus = JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 31);
    let mut sgl = SgLang::build(model.clone(), hw.clone(), &pop, 32);
    let mut msi = MegaScaleInfer::build(model.clone(), hw.clone(), &pop, 16, 33);
    let mut xds = XDeepServe::build(model, hw, &pop, 32, 34);
    let systems: Vec<&mut dyn ServingSystem> = vec![&mut janus, &mut sgl, &mut msi, &mut xds];
    for sys in systems {
        for sc in &scenarios {
            match engine::run(sys, sc, 12).expect("valid scenario") {
                ScenarioOutcome::FixedBatch(r) => {
                    assert!(r.tpot_mean > 0.0 && r.gpus > 0, "{}", r.system);
                }
                ScenarioOutcome::Autoscale(r) => {
                    assert_eq!(r.intervals.len(), 4, "{}", r.system);
                    assert!(r.gpu_hours > 0.0, "{}", r.system);
                    assert!(r.steps > 0 && r.admitted_requests > 0, "{}", r.system);
                }
                ScenarioOutcome::FailureInjection(r) => {
                    assert!(r.steps > 0, "{}", r.system);
                    assert_eq!(r.reconfigurations, 2, "{}", r.system);
                    assert_eq!(r.tpot.count(), r.steps, "{}", r.system);
                }
            }
        }
    }
}

/// Seeded-determinism contract: repeating any scenario with the same seed
/// (and a freshly built system) yields bit-identical metrics.
#[test]
fn engine_scenarios_are_bit_deterministic() {
    let build = || {
        JanusSystem::build(
            models::deepseek_v2(),
            janus::config::hardware::autoscale_pool(),
            &ExpertPopularity::Zipf { s: 0.4 },
            16,
            55,
        )
    };
    let slo = Slo::from_ms(200.0);
    let scenarios = [
        Scenario::FixedBatch(FixedBatchScenario {
            batch: 256,
            slo,
            steps: 12,
        }),
        Scenario::Autoscale(AutoscaleScenario::new(
            120.0,
            32.0,
            slo,
            DiurnalTrace::ramp(360.0 / 3600.0, 30.0, 1.0, 6.0, 55),
        )),
        Scenario::FailureInjection(
            FailureScenario::new(slo, 3.0, 48.0, 240.0).with_failure(80.0, 12, 100.0),
        ),
    ];
    for sc in &scenarios {
        let fingerprint = |outcome: ScenarioOutcome| -> Vec<u64> {
            match outcome {
                ScenarioOutcome::FixedBatch(r) => vec![
                    r.tpot_mean.to_bits(),
                    r.tpot_p99.to_bits(),
                    r.tpg.to_bits(),
                    r.a_max_mean.to_bits(),
                ],
                ScenarioOutcome::Autoscale(r) => vec![
                    r.gpu_hours.to_bits(),
                    r.feasible_fraction.to_bits(),
                    r.tpot_mean.to_bits(),
                    r.tpot_p99.to_bits(),
                    r.admission_delay_p99.to_bits(),
                    r.ttft_p99.to_bits(),
                    r.queue_depth_mean.to_bits(),
                    r.min_gpus as u64,
                    r.max_gpus as u64,
                    r.steps as u64,
                    r.admitted_requests as u64,
                    r.completed_requests as u64,
                    r.rejected_requests as u64,
                    r.generated_tokens as u64,
                ],
                ScenarioOutcome::FailureInjection(r) => vec![
                    r.tpot.mean().to_bits(),
                    r.gpu_hours.to_bits(),
                    r.slo_attainment.to_bits(),
                    r.steps as u64,
                    r.completed_requests as u64,
                ],
            }
        };
        let a = fingerprint(engine::run(&mut build(), sc, 99).expect("valid scenario"));
        let b = fingerprint(engine::run(&mut build(), sc, 99).expect("valid scenario"));
        assert_eq!(a, b, "scenario replay must be bit-identical");
    }
}

/// Failure injection end to end: killing most of the per-side instance
/// budget makes re-placement infeasible (the survivors cannot seat every
/// expert), the decode loop keeps serving on the emergency layout, and
/// recovery restores feasibility.
#[test]
fn failure_injection_measures_replacement() {
    let slo = Slo::from_ms(200.0);
    let sc = FailureScenario::new(slo, 4.0, 64.0, 600.0).with_failure(120.0, 28, 240.0);
    let mut janus = JanusSystem::build(
        models::deepseek_v2(),
        janus::config::hardware::autoscale_pool(),
        &ExpertPopularity::Uniform,
        32,
        71,
    );
    let r = engine::failure_injection(&mut janus, &sc, 13).expect("valid scenario");
    assert!(r.steps > 0 && r.completed_requests > 0);
    assert!(r.degraded_steps > 0 && r.degraded_steps < r.steps);
    assert!(
        r.feasible_fraction < 1.0,
        "28/32 instances lost must make outage decisions infeasible"
    );
    assert!(r.feasible_fraction > 0.0);
    assert!(janus.configure_for_demand(256.0, slo).is_some(), "pool recovered");
}

/// Memoized scaling decisions are observationally invisible: for every
/// system, repeating a decision on an unchanged pool (a guaranteed cache
/// hit) returns the same configuration and leaves the system stepping
/// exactly as a cold-cache search would — the property that lets the
/// decision cache sit on the autoscale loop without moving a single
/// golden-snapshot bit.
#[test]
fn decision_memoization_changes_no_outcome() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Uniform;
    let slo = Slo::from_ms(200.0);
    let mut janus = JanusSystem::build(model.clone(), hw.clone(), &pop, 16, 91);
    let mut sgl = SgLang::build(model.clone(), hw.clone(), &pop, 92);
    let mut msi = MegaScaleInfer::build(model.clone(), hw.clone(), &pop, 16, 93);
    let mut xds = XDeepServe::build(model, hw, &pop, 32, 94);
    let systems: Vec<&mut dyn ServingSystem> = vec![&mut janus, &mut sgl, &mut msi, &mut xds];
    for sys in systems {
        let cold = sys.configure_for_demand(3000.0, slo);
        let cold_gpus = sys.gpus();
        let cold_label = sys.label();
        let cold_cap = sys.batch_capacity();
        let mut rng = Rng::seed_from_u64(17);
        let cold_step = sys.step(128, &mut rng);
        let hit = sys.configure_for_demand(3000.0, slo);
        assert_eq!(cold, hit, "{}: cache hit changed the decision", sys.name());
        assert_eq!(cold_gpus, sys.gpus(), "{}", sys.name());
        assert_eq!(cold_label, sys.label(), "{}", sys.name());
        assert_eq!(cold_cap, sys.batch_capacity(), "{}", sys.name());
        let mut rng = Rng::seed_from_u64(17);
        let hit_step = sys.step(128, &mut rng);
        assert_eq!(cold_step, hit_step, "{}: post-hit step diverged", sys.name());
    }
}

/// Static expert parallelism (no redundancy) leaves no scheduling choice:
/// AEBS degenerates gracefully and still matches baselines exactly.
#[test]
fn no_redundancy_degenerate_case() {
    let mut rng = Rng::seed_from_u64(17);
    let placement = ExpertPlacement::contiguous(160, 8, 20);
    let gate = GateSim::new(160, 6, &ExpertPopularity::Uniform, &mut rng);
    for _ in 0..10 {
        let b = gate.sample_batch(&mut rng, 128);
        let a1 = aebs::assign(&b, &placement);
        let a2 = scheduler::baselines::static_first(&b, &placement);
        assert_eq!(a1.instance_of, a2.instance_of);
    }
}
