//! Tier-1 pins for the observability plane (`rust/src/obs`):
//!
//! - **Bit-exact phase attribution**: for every evaluation system, the
//!   step's phase lanes sum to the step's charged TPOT to the bit
//!   (`StepPhases::total().to_bits() == out.tpot.to_bits()`), and the
//!   attribution is real (not the collapsed fallback).
//! - **Mode transparency**: `run_with_recorder` at off / counters /
//!   full produces bit-identical scenario outcomes — the recorder can
//!   never perturb the simulated floats.
//! - **Ledger conservation**: the phase ledger's total equals the sum
//!   of charged step times.
//! - **Trace byte determinism**: rerunning the same cell grid yields
//!   byte-identical Chrome-trace JSON and metrics TSV (thread-count
//!   invariance is pinned in `tests/sweep_determinism.rs`).
//!
//! Every cell pins its modes explicitly, so this file passes
//! identically under every `JANUS_OBS` / `JANUS_ADMISSION` /
//! `JANUS_SCALING` / `JANUS_FAULTS` CI leg.

use janus::baselines::{build_eval_system, EVAL_SYSTEMS};
use janus::config::hardware::paper_testbed;
use janus::config::models;
use janus::config::serving::Slo;
use janus::obs::{Counter, ObsMode, Recorder};
use janus::routing::gate::ExpertPopularity;
use janus::sim::engine::{
    run_with_recorder, FixedBatchScenario, Scenario, ScenarioOutcome,
};
use janus::sim::tracegen::{sample_bundle, sample_cells};
use janus::util::rng::Rng;

fn pop() -> ExpertPopularity {
    ExpertPopularity::Zipf { s: 0.4 }
}

/// The acceptance-criterion pin: per-step phase lanes sum exactly (to
/// the bit) to the step's charged latency, for all four systems.
#[test]
fn step_phases_total_is_bit_exact_for_every_system() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let slo = Slo::from_ms(200.0);
    for which in 0..EVAL_SYSTEMS {
        let mut sys = build_eval_system(which, model.clone(), hw.clone(), &pop());
        let cfg = sys.configure(64, slo);
        assert!(cfg.is_some(), "system {which} infeasible at B=64/200ms");
        let mut rng = Rng::seed_from_u64(7);
        for step in 0..25 {
            let out = sys.step(64, &mut rng);
            let phases = sys.step_phases();
            assert_eq!(
                phases.total().to_bits(),
                out.tpot.to_bits(),
                "system {which} step {step}: lanes {phases:?} do not sum to tpot {}",
                out.tpot,
            );
            assert!(
                phases.attributed(),
                "system {which} step {step}: attribution collapsed to a single lane"
            );
            assert!(
                phases.attention > 0.0 && phases.expert > 0.0,
                "system {which} step {step}: empty attention/expert lanes in {phases:?}"
            );
        }
    }
}

/// `reconciled` must accept a bit-exact attribution unchanged and
/// collapse a mismatched one rather than misreport.
#[test]
fn reconcile_accepts_exact_and_collapses_mismatch() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let mut sys = build_eval_system(0, model, hw, &pop());
    sys.configure(64, Slo::from_ms(200.0));
    let mut rng = Rng::seed_from_u64(11);
    let out = sys.step(64, &mut rng);
    let phases = sys.step_phases();
    let kept = phases.reconciled(out.tpot);
    assert_eq!(kept.attention.to_bits(), phases.attention.to_bits());
    assert!(kept.attributed());
    let collapsed = phases.reconciled(out.tpot * 2.0);
    assert!(!collapsed.attributed());
    assert_eq!(collapsed.total().to_bits(), (out.tpot * 2.0).to_bits());
}

/// Scenario outcomes are bit-identical across observability modes: the
/// recorder observes, it never participates.
#[test]
fn outcomes_identical_across_obs_modes() {
    let cells = sample_cells();
    for cell in &cells {
        let mut outs = Vec::new();
        for mode in [ObsMode::Off, ObsMode::Counters, ObsMode::Full] {
            let mut sys = (cell.build)();
            let mut rec = Recorder::new(mode);
            let out = run_with_recorder(sys.as_mut(), &cell.scenario, cell.seed, &mut rec)
                .expect("sample cells are valid scenarios");
            outs.push(format!("{out:?}"));
        }
        assert_eq!(outs[0], outs[1], "{}: off vs counters outcome drift", cell.label);
        assert_eq!(outs[0], outs[2], "{}: off vs full outcome drift", cell.label);
    }
}

/// Off-mode recorders record literally nothing.
#[test]
fn off_mode_records_nothing() {
    let cells = sample_cells();
    let cell = &cells[0];
    let mut sys = (cell.build)();
    let mut rec = Recorder::new(ObsMode::Off);
    run_with_recorder(sys.as_mut(), &cell.scenario, cell.seed, &mut rec)
        .expect("valid scenario");
    assert!(!rec.enabled());
    assert!(rec.counters().iter().all(|&c| c == 0));
    assert!(rec.events().is_empty());
    assert_eq!(rec.ledger().decode_steps(), 0);
    assert_eq!(rec.ledger().total(), 0.0);
}

/// The ledger conserves charged time: its lane total equals the sum of
/// every step's charged duration, and the decode-step counter matches
/// the scenario's reported step count.
#[test]
fn ledger_total_matches_charged_step_time() {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let scenario = Scenario::FixedBatch(FixedBatchScenario {
        batch: 64,
        slo: Slo::from_ms(200.0),
        steps: 30,
    });
    for which in 0..EVAL_SYSTEMS {
        // Reference run: same seed, recorder off — sum the charged tpots.
        let mut sys = build_eval_system(which, model.clone(), hw.clone(), &pop());
        let mut off = Recorder::disabled();
        let reference = run_with_recorder(sys.as_mut(), &scenario, 77, &mut off)
            .expect("fixed batch always valid");
        let mut sys = build_eval_system(which, model.clone(), hw.clone(), &pop());
        let mut rec = Recorder::new(ObsMode::Counters);
        let outcome = run_with_recorder(sys.as_mut(), &scenario, 77, &mut rec)
            .expect("fixed batch always valid");
        assert_eq!(format!("{reference:?}"), format!("{outcome:?}"));
        let r = match outcome {
            ScenarioOutcome::FixedBatch(r) => r,
            other => panic!("unexpected outcome {other:?}"),
        };
        assert_eq!(rec.counter(Counter::DecodeSteps), 30);
        assert_eq!(rec.ledger().decode_steps(), 30);
        // Lane total ≈ steps × mean tpot (the result's mean is the same
        // accumulation divided by the count, so agreement here is tight).
        let charged = r.tpot_mean * 30.0;
        let total = rec.ledger().total();
        assert!(
            (total - charged).abs() <= 1e-9 * charged.max(1.0),
            "system {which}: ledger {total} vs charged {charged}"
        );
        assert_eq!(
            rec.counter(Counter::UnattributedSteps),
            0,
            "system {which}: collapsed attributions in a clean fixed-batch run"
        );
    }
}

/// Rerunning the canonical grid reproduces the trace and metrics bytes
/// exactly — the foundation of the CI artifact's stability.
#[test]
fn trace_bytes_are_rerun_identical() {
    let a = sample_bundle(ObsMode::Full, 2);
    let b = sample_bundle(ObsMode::Full, 2);
    assert_eq!(a.trace_json, b.trace_json);
    assert_eq!(a.metrics_tsv, b.metrics_tsv);
    assert!(!a.trace_json.is_empty());
    // Spot-check the export shape: valid Chrome-trace JSON array and a
    // TSV metrics block with the lane rows.
    assert!(a.trace_json.starts_with("[\n"));
    assert!(a.trace_json.ends_with("\n]\n"));
    assert!(a.metrics_tsv.contains("counter\tdecode_steps"));
    assert!(a.metrics_tsv.contains("lane\tattention"));
}
