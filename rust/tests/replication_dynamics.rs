//! Replication-dynamics acceptance (tier-1): availability-aware
//! replication must be *provably* better through the fault plane, not
//! just plausibly different.
//!
//! - Engine level (scripted mock, so the contrast is pure recovery
//!   semantics): under an identical seeded `FaultPlan` and the `replica`
//!   degradation policy, a coact-style recovery (every lost expert
//!   re-seated, service restored early) yields strictly lower
//!   `mttr_mean` and strictly higher `availability` than a static-style
//!   recovery (saturated placement, dropped experts, full-window
//!   outage). The rows are bit-identical across sweep worker counts.
//! - System level (real `JanusSystem` at a pinned 8-instance MoE pool):
//!   a static placement saturates every slot, so some crash drops a
//!   sole-replica expert and can never declare restoration; the coact
//!   placement keeps headroom and recovers *every* crash with zero
//!   drops and an early service-restored declaration.
//! - `JANUS_REPLICATION` resolution: default builds follow the env knob
//!   (the CI replication matrix runs this suite under both legs), while
//!   golden/determinism surfaces pin `Static` explicitly elsewhere.

use janus::baselines::{JanusSystem, ServingSystem};
use janus::config::hardware::paper_testbed;
use janus::config::models;
use janus::config::serving::{Deployment, Slo};
use janus::placement::{ReplicationMode, REPLICATION_ENV};
use janus::routing::gate::ExpertPopularity;
use janus::scaling::ScalingMode;
use janus::sim::admission::AdmissionConfig;
use janus::sim::engine::{failure_injection, FailureScenario};
use janus::sim::faults::{DegradationPolicy, FaultPlan};
use janus::sim::sweep::{self, sweep};
use janus::testing::MockServingSystem;

const SEED: u64 = 424242;
const CRASH_AT: f64 = 30.0;
const CRASH_DURATION: f64 = 60.0;
const HORIZON: f64 = 180.0;

/// One instance crash under the `replica` policy — the scenario both
/// recovery styles run against, identically.
fn replica_crash_scenario() -> FailureScenario {
    let plan = FaultPlan::new()
        .with_instance_crash(CRASH_AT, CRASH_DURATION, 0)
        .with_policy(DegradationPolicy::Replica);
    let mut sc =
        FailureScenario::new(Slo::from_ms(200.0), 2.0, 32.0, HORIZON).with_faults(plan);
    sc.admission = AdmissionConfig::fifo();
    sc.scaling = ScalingMode::Reactive;
    sc
}

/// Static stand-in: narrowed recovery with zero free slots — nothing
/// moves, three sole-replica experts drop, no restoration is declared.
fn static_style_mock() -> MockServingSystem {
    MockServingSystem::new(4, 64, 0.01)
        .with_narrowed_crash(0, 0.0)
        .with_crash_dropped(3)
}

/// Coact stand-in: every lost expert re-seated from survivors and
/// service declared restored 2 s after the crash.
fn coact_style_mock() -> MockServingSystem {
    MockServingSystem::new(4, 64, 0.01)
        .with_narrowed_crash(5, 0.4)
        .with_restored_secs(2.0)
}

#[test]
fn coact_recovery_strictly_beats_static_on_mttr_and_availability() {
    let sc = replica_crash_scenario();
    let mut st_sys = static_style_mock();
    let st = failure_injection(&mut st_sys, &sc, SEED).expect("valid scenario");
    let mut co_sys = coact_style_mock();
    let co = failure_injection(&mut co_sys, &sc, SEED).expect("valid scenario");

    // Both runs saw exactly the one scripted crash, recovered narrowed.
    assert_eq!(st.faults.events.len(), 1);
    assert_eq!(co.faults.events.len(), 1);
    assert!(st.faults.events[0].narrowed && !st.faults.events[0].feasible);
    assert!(co.faults.events[0].narrowed && co.faults.events[0].feasible);

    // Static pays the full fault window as MTTR; coact pays its declared
    // restore time and closes the degraded window early.
    assert!((st.mttr_mean - CRASH_DURATION).abs() < 1e-9);
    assert!((co.mttr_mean - 2.0).abs() < 1e-9);
    assert_eq!(st.faults.early_repairs, 0);
    assert_eq!(co.faults.early_repairs, 1);

    // The headline invariants, strict.
    assert!(
        co.mttr_mean < st.mttr_mean,
        "coact mttr {} must be strictly below static's {}",
        co.mttr_mean,
        st.mttr_mean
    );
    assert!(
        co.availability > st.availability,
        "coact availability {} must strictly exceed static's {}",
        co.availability,
        st.availability
    );
}

/// The comparison rows are a pure function of (mode, scenario, seed):
/// serializing both cells through `sim::sweep` is byte-identical at any
/// worker count, so the CI thread matrix pins one set of bytes.
#[test]
fn replication_rows_are_byte_identical_across_thread_counts() {
    fn rows(threads: usize) -> String {
        let modes = ["static", "coact"];
        sweep(&modes, threads, |_, &mode| {
            let sc = replica_crash_scenario();
            let mut sys = if mode == "static" {
                static_style_mock()
            } else {
                coact_style_mock()
            };
            let r = failure_injection(&mut sys, &sc, SEED).expect("valid scenario");
            format!(
                "{mode}\t{:016x}\t{:016x}\t{:016x}\t{}\t{}\t{}\n",
                r.availability.to_bits(),
                r.mttr_mean.to_bits(),
                r.faults.degraded_time.to_bits(),
                r.faults.early_repairs,
                r.faults.events.len(),
                r.steps,
            )
        })
        .concat()
    }
    let serial = rows(1);
    assert_eq!(serial.lines().count(), 2);
    assert_eq!(serial, rows(2), "threads=2");
    let parallel = if sweep::hardware_threads() >= 4 { 4 } else { 2 };
    assert_eq!(serial, rows(parallel), "threads={parallel}");
}

/// Real Janus at a pinned 8-instance MoE pool (27 expert slots each,
/// 160 logical experts — the coact zero-drop regime).
fn build_janus(mode: ReplicationMode) -> JanusSystem {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = ExpertPopularity::Zipf { s: 1.2 };
    let mut sys = JanusSystem::build_with_replication(model, hw, &pop, 16, 47, mode);
    sys.deploy(Deployment::new(4, 8));
    sys
}

#[test]
fn static_placement_drops_where_coact_restores_every_crash() {
    let slo = Slo::from_ms(200.0);
    let n_moe = 8u32;

    // Static saturates every slot: no crash can re-seat anything, and
    // at least one victim instance hosts a sole-replica expert whose
    // loss is permanent (216 slots < 2 x 160 experts, pigeonhole).
    let mut static_dropped = false;
    for victim in 0..n_moe {
        let mut sys = build_janus(ReplicationMode::Static);
        let a = sys.crash_instance(victim, DegradationPolicy::Replica, 2.0, slo);
        assert!(a.narrowed, "victim {victim}: Janus recovers narrowed");
        assert_eq!(a.moved_experts, 0, "victim {victim}: zero free slots");
        assert_eq!(a.re_replicated_experts, 0, "victim {victim}: static never re-replicates");
        assert_eq!(a.restored_secs, None, "victim {victim}: static never declares restore");
        if a.dropped_experts > 0 {
            assert!(!a.feasible, "victim {victim}: dropped experts => infeasible");
            static_dropped = true;
        }
    }
    assert!(
        static_dropped,
        "no static crash dropped an expert — headroom appeared where none should exist"
    );

    // Coact keeps headroom and an eviction fallback: EVERY crash
    // recovers with zero dropped experts and declares restoration.
    let mut restored_early = false;
    for victim in 0..n_moe {
        let mut sys = build_janus(ReplicationMode::Coact);
        let a = sys.crash_instance(victim, DegradationPolicy::Replica, 2.0, slo);
        assert!(a.narrowed && a.feasible, "victim {victim}: coact crash must stay feasible");
        assert_eq!(a.dropped_experts, 0, "victim {victim}: coact must not drop");
        let restored = a
            .restored_secs
            .unwrap_or_else(|| panic!("victim {victim}: coact must declare restoration"));
        assert!(
            (restored - (a.transfer_secs + a.background_secs)).abs() < 1e-12,
            "victim {victim}: restore time is the repair transfer total"
        );
        if restored > 0.0 {
            restored_early = true;
        }
    }
    assert!(
        restored_early,
        "every coact crash restored in zero time — no repair work was modeled"
    );
}

#[test]
fn replication_mode_resolves_from_env_consistently() {
    assert_eq!(ReplicationMode::Static.name(), "static");
    assert_eq!(ReplicationMode::Coact.name(), "coact");
    assert_eq!(
        ReplicationMode::ALL,
        [ReplicationMode::Static, ReplicationMode::Coact]
    );

    // Default builds follow JANUS_REPLICATION (the CI matrix runs this
    // suite under both legs); unset or unparseable means static.
    let want = match std::env::var(REPLICATION_ENV).ok().as_deref() {
        Some(v) if v.trim().eq_ignore_ascii_case("coact") => ReplicationMode::Coact,
        _ => ReplicationMode::Static,
    };
    assert_eq!(ReplicationMode::from_env(), want);
    let sys = JanusSystem::build(
        models::deepseek_v2(),
        paper_testbed(),
        &ExpertPopularity::Zipf { s: 0.4 },
        16,
        42,
    );
    assert_eq!(sys.replication_mode(), want);
}
