//! Determinism pin for the parallel sweep engine (tier-1).
//!
//! The `sim::sweep` contract: worker count is not an observable. The
//! same cell list produces byte-identical serialized results at
//! `threads = 1` and at `threads = 4` (falling back to 2 when the
//! machine has fewer than 4 hardware threads — the claim-race coverage
//! only needs > 1 worker), a cell's RNG streams are a pure function
//! of the cell — worker scheduling cannot perturb them — and the
//! chunked work claiming (K cells per `fetch_add`, `JANUS_CHUNK`)
//! is equally unobservable for K ∈ {1, 3, grid-size}.

use janus::baselines::{build_eval_system, JanusSystem, ServingSystem};
use janus::config::hardware::paper_testbed;
use janus::config::models;
use janus::config::serving::Slo;
use janus::placement::ReplicationMode;
use janus::scaling::ScalingMode;
use janus::sim::admission::AdmissionConfig;
use janus::sim::engine::{
    failure_injection, AutoscaleScenario, FailureResult, FailureScenario, FixedBatchScenario,
    Scenario, ScenarioOutcome,
};
use janus::sim::faults::{DegradationPolicy, FaultPlan};
use janus::sim::sweep::{self, run_cells, sweep, sweep_chunked, SweepCell};
use janus::util::rng::{split_seed, Rng};
use janus::workload::trace::DiurnalTrace;

/// What the autoscale cells run: reactive (envelope-only) or the
/// closed signal-driven loop. Pinned per cell — never `from_env` — so
/// the sweep bytes are identical under every `JANUS_SCALING` CI leg.
const MODES: [(ScalingMode, &str); 2] = [
    (ScalingMode::Reactive, "auto"),
    (ScalingMode::Closed, "closed"),
];

/// Serialize a representative evaluation sweep — 4 systems × 2 batches
/// of fixed-batch decode plus two arrival-driven autoscale cells per
/// system (one reactive, one closed-loop), expressed as a `SweepCell`
/// (system ctor × scenario × seed) work queue drained by `run_cells` —
/// to an exact (bit-level hex) string. Heavy and light cells interleave
/// in one queue so worker claiming is genuinely racy at > 1 thread.
fn sweep_snapshot(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let trace = DiurnalTrace::ramp(300.0 / 3600.0, 30.0, 1.0, 6.0, 77);
    let names = ["janus", "sglang", "msi", "xds"];
    let mut cells: Vec<SweepCell> = Vec::new();
    for s in 0..4usize {
        let mut auto_cell = |mode: usize| -> (Scenario, String) {
            let mut sc =
                AutoscaleScenario::new(75.0, 32.0, Slo::from_ms(200.0), trace.clone());
            sc.scaling = MODES[mode].0;
            (
                Scenario::Autoscale(sc),
                format!("{}/{}", names[s], MODES[mode].1),
            )
        };
        // Two fixed-batch cells, then one autoscale cell per scaling mode.
        for kind in 0..4usize {
            let (scenario, label) = if kind < 2 {
                let b = [64usize, 256][kind];
                (
                    Scenario::FixedBatch(FixedBatchScenario {
                        batch: b,
                        slo: Slo::from_ms(200.0),
                        steps: 12,
                    }),
                    format!("{}/B{b}", names[s]),
                )
            } else {
                auto_cell(kind - 2)
            };
            cells.push(SweepCell {
                label,
                build: Box::new({
                    let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
                    move || -> Box<dyn ServingSystem> {
                        build_eval_system(s, model.clone(), hw.clone(), &pop)
                    }
                }),
                scenario,
                seed: 9,
            });
        }
    }
    run_cells(&cells, threads)
        .iter()
        .map(|cell| match cell.outcome.as_ref().expect("valid scenario") {
            ScenarioOutcome::FixedBatch(r) => format!(
                "{}\t{:016x}\t{:016x}\t{:016x}\n",
                cell.label,
                r.tpot_mean.to_bits(),
                r.tpot_p99.to_bits(),
                r.tpg.to_bits()
            ),
            ScenarioOutcome::Autoscale(r) => format!(
                "{}\t{:016x}\t{:016x}\t{}\t{}\t{}\n",
                cell.label,
                r.gpu_hours.to_bits(),
                r.tpot_p99.to_bits(),
                r.steps,
                r.admitted_requests,
                r.generated_tokens
            ),
            ScenarioOutcome::FailureInjection(_) => {
                unreachable!("no failure cells in this sweep")
            }
        })
        .collect()
}

#[test]
fn sweep_is_byte_identical_across_thread_counts() {
    let serial = sweep_snapshot(1);
    assert!(serial.lines().count() == 16, "unexpected cell count");
    // Both scaling modes made it into the queue.
    assert_eq!(serial.lines().filter(|l| l.contains("/auto")).count(), 4);
    assert_eq!(serial.lines().filter(|l| l.contains("/closed")).count(), 4);
    // 4 workers when the hardware has them, else the 2-worker fallback —
    // plus a deliberately oversubscribed count, which must not matter
    // either (workers beyond the cell list just find it drained).
    let parallel = if sweep::hardware_threads() >= 4 { 4 } else { 2 };
    assert_eq!(serial, sweep_snapshot(parallel), "threads={parallel}");
    assert_eq!(serial, sweep_snapshot(2), "threads=2");
    assert_eq!(serial, sweep_snapshot(64), "threads=64 (oversubscribed)");
}

/// Bit-level serialization of one failure-injection outcome, shared by
/// the fault-plan determinism and legacy-pin tests below.
fn fault_row(r: &FailureResult) -> String {
    format!(
        "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:016x}\t{:016x}\t{:016x}\t{:016x}\t{}\t{:016x}\n",
        r.steps,
        r.admitted_requests,
        r.completed_requests,
        r.rejected_requests,
        r.generated_tokens,
        r.preemptions,
        r.shed_requests,
        r.availability.to_bits(),
        r.mttr_mean.to_bits(),
        r.tpot.mean().to_bits(),
        r.gpu_hours.to_bits(),
        r.faults.events.len(),
        r.faults.degraded_time.to_bits(),
    )
}

/// Serialize a fault-plane sweep — all four systems × all three
/// degradation policies, each cell exercising every fault kind —
/// at a given worker count. Policies are pinned per cell (never
/// `from_env`), so the bytes are identical under every `JANUS_FAULTS`
/// CI leg.
fn fault_sweep_snapshot(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let names = ["janus", "sglang", "msi", "xds"];
    let mut cells: Vec<SweepCell> = Vec::new();
    for s in 0..4usize {
        for (p_i, policy) in DegradationPolicy::ALL.into_iter().enumerate() {
            let plan = FaultPlan::new()
                .with_instance_crash(30.0, 60.0, 0)
                .with_straggler(50.0, 40.0, 2.0)
                .with_transient_comm(100.0, 20.0, 0.5)
                .with_attention_host_loss(140.0, 20.0, 1, p_i % 2 == 0)
                .with_policy(policy);
            let mut sc =
                FailureScenario::new(Slo::from_ms(200.0), 2.0, 32.0, 180.0).with_faults(plan);
            sc.admission = AdmissionConfig::fifo();
            sc.scaling = ScalingMode::Reactive;
            cells.push(SweepCell {
                label: format!("{}/{}", names[s], policy.name()),
                build: Box::new({
                    let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
                    move || -> Box<dyn ServingSystem> {
                        build_eval_system(s, model.clone(), hw.clone(), &pop)
                    }
                }),
                scenario: Scenario::FailureInjection(sc),
                seed: 31,
            });
        }
    }
    run_cells(&cells, threads)
        .iter()
        .map(|cell| match cell.outcome.as_ref().expect("valid scenario") {
            ScenarioOutcome::FailureInjection(r) => {
                format!("{}\t{}", cell.label, fault_row(r))
            }
            _ => unreachable!("fault sweep only holds failure cells"),
        })
        .collect()
}

#[test]
fn fault_plan_cells_are_byte_identical_across_thread_counts() {
    let serial = fault_sweep_snapshot(1);
    assert_eq!(serial.lines().count(), 12, "4 systems x 3 policies");
    let parallel = if sweep::hardware_threads() >= 4 { 4 } else { 2 };
    assert_eq!(serial, fault_sweep_snapshot(parallel), "threads={parallel}");
    assert_eq!(serial, fault_sweep_snapshot(2), "threads=2");
}

/// Serialize a replication-mode fault sweep — the real JanusSystem
/// built under each [`ReplicationMode`], run through the engine against
/// an identical crash-plus-straggler plan under the replica policy.
/// Modes are pinned per cell (never `from_env`), so the bytes are
/// identical under every `JANUS_REPLICATION` CI leg — and the coact
/// cell drives the full dynamic pipeline (decayed stats, headroom
/// placement, eviction recovery, re-replication, prefetch staging)
/// through the same determinism contract as everything else.
fn replication_sweep_snapshot(threads: usize) -> String {
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let mut cells: Vec<SweepCell> = Vec::new();
    for mode in ReplicationMode::ALL {
        let plan = FaultPlan::new()
            .with_instance_crash(30.0, 60.0, 0)
            .with_straggler(50.0, 40.0, 2.0)
            .with_policy(DegradationPolicy::Replica);
        let mut sc =
            FailureScenario::new(Slo::from_ms(200.0), 2.0, 32.0, 180.0).with_faults(plan);
        sc.admission = AdmissionConfig::fifo();
        sc.scaling = ScalingMode::Reactive;
        cells.push(SweepCell {
            label: format!("janus/{}", mode.name()),
            build: Box::new({
                let (model, hw, pop) = (model.clone(), hw.clone(), pop.clone());
                move || -> Box<dyn ServingSystem> {
                    Box::new(JanusSystem::build_with_replication(
                        model.clone(),
                        hw.clone(),
                        &pop,
                        16,
                        31,
                        mode,
                    ))
                }
            }),
            scenario: Scenario::FailureInjection(sc),
            seed: 31,
        });
    }
    run_cells(&cells, threads)
        .iter()
        .map(|cell| match cell.outcome.as_ref().expect("valid scenario") {
            ScenarioOutcome::FailureInjection(r) => {
                format!("{}\t{}", cell.label, fault_row(r))
            }
            _ => unreachable!("replication sweep only holds failure cells"),
        })
        .collect()
}

#[test]
fn replication_cells_are_byte_identical_across_thread_counts() {
    let serial = replication_sweep_snapshot(1);
    assert_eq!(serial.lines().count(), 2, "one cell per replication mode");
    assert_eq!(serial, replication_sweep_snapshot(2), "threads=2");
    let parallel = if sweep::hardware_threads() >= 4 { 4 } else { 2 };
    assert_eq!(serial, replication_sweep_snapshot(parallel), "threads={parallel}");
}

#[test]
fn static_replication_build_matches_legacy_eval_bytes() {
    // The bit-identity contract at the constructor surface: building
    // Janus with `ReplicationMode::Static` pinned explicitly must
    // serialize a whole engine run to exactly the bytes of the
    // env-immune canonical eval build (same ctor seed 42 / n_max 16) —
    // the static path performs no extra RNG draws, no forecaster
    // observations, and no float work.
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let plan = FaultPlan::new()
        .with_instance_crash(30.0, 60.0, 0)
        .with_policy(DegradationPolicy::Replica);
    let mut sc =
        FailureScenario::new(Slo::from_ms(200.0), 2.0, 32.0, 180.0).with_faults(plan);
    sc.admission = AdmissionConfig::fifo();
    sc.scaling = ScalingMode::Reactive;
    let legacy = {
        let mut sys = build_eval_system(0, model.clone(), hw.clone(), &pop);
        fault_row(&failure_injection(sys.as_mut(), &sc, 47).expect("valid scenario"))
    };
    let explicit = {
        let mut sys = JanusSystem::build_with_replication(
            model,
            hw,
            &pop,
            16,
            42,
            ReplicationMode::Static,
        );
        fault_row(&failure_injection(&mut sys, &sc, 47).expect("valid scenario"))
    };
    assert_eq!(legacy, explicit);
}

#[test]
fn empty_fault_plan_run_matches_legacy_bytes() {
    // The bit-identity contract at the sweep surface: installing a
    // FaultPlan that schedules nothing must serialize to exactly the
    // legacy scenario's bytes (no extra RNG draws, no per-step charges).
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let mut legacy = FailureScenario::new(Slo::from_ms(200.0), 2.0, 32.0, 240.0)
        .with_failure(60.0, 8, 90.0);
    legacy.admission = AdmissionConfig::fifo();
    legacy.scaling = ScalingMode::Reactive;
    let mut pinned = legacy.clone();
    pinned.faults = Some(FaultPlan::new().with_policy(DegradationPolicy::Off));
    let row = |sc: &FailureScenario| -> String {
        let mut sys = build_eval_system(0, model.clone(), hw.clone(), &pop);
        fault_row(&failure_injection(sys.as_mut(), sc, 47).expect("valid scenario"))
    };
    assert_eq!(row(&legacy), row(&pinned));
}

#[test]
fn worker_scheduling_cannot_perturb_per_cell_rng_streams() {
    // Seed-ordering pin: every cell derives its RNG with
    // split_seed(stream, cell_id). The resulting draw sequence must be
    // a function of the cell alone — equal across worker counts, equal
    // when the cell runs in a different submission slot, and equal when
    // the cell runs in a sweep of one.
    let draws = |cell: u64| -> Vec<u64> {
        let mut rng = Rng::seed_from_u64(split_seed(0x5EED, cell));
        (0..32).map(|_| rng.next_u64()).collect()
    };
    let cells: Vec<u64> = (0..24).collect();
    let run = |threads: usize, order: &[u64]| -> Vec<Vec<u64>> {
        sweep(order, threads, |_, &c| draws(c))
    };
    let serial = run(1, &cells);
    for threads in [2usize, 4, 8] {
        assert_eq!(serial, run(threads, &cells), "threads={threads}");
    }
    // Solo runs reproduce in-sweep values: no cross-cell contamination.
    for k in [0usize, 11, 23] {
        let solo = run(4, &cells[k..=k]);
        assert_eq!(solo[0], serial[k], "cell {k} depends on sweep context");
    }
    // Permuted submission: results permute with the cells (slot i holds
    // f(cells[i]), never a scheduling-dependent value).
    let reversed: Vec<u64> = cells.iter().rev().copied().collect();
    let rev_results = run(4, &reversed);
    for (i, &c) in reversed.iter().enumerate() {
        assert_eq!(rev_results[i], serial[c as usize], "slot {i}");
    }
}

#[test]
fn chunked_claiming_is_byte_identical_for_k_1_3_and_grid_size() {
    // Chunked work claiming (K cells per fetch_add) must not be an
    // observable either: for K ∈ {1, 3, grid-size} the simulation sweep
    // serializes to the same bytes as the serial run. K = 1 is the
    // classic one-cell claim; K = grid-size degenerates to one worker
    // draining everything while the others find the queue empty.
    let model = models::deepseek_v2();
    let hw = paper_testbed();
    let pop = janus::routing::gate::ExpertPopularity::Zipf { s: 0.4 };
    let cells: Vec<(usize, usize)> = (0..4usize)
        .flat_map(|s| [32usize, 64, 96].into_iter().map(move |b| (s, b)))
        .collect();
    let grid = cells.len();
    let run = |threads: usize, chunk: usize| -> String {
        sweep_chunked(&cells, threads, chunk, |_, &(s, batch)| {
            let mut sys = build_eval_system(s, model.clone(), hw.clone(), &pop);
            let r = janus::sim::engine::fixed_batch(
                sys.as_mut(),
                &FixedBatchScenario {
                    batch,
                    slo: Slo::from_ms(200.0),
                    steps: 6,
                },
                13,
            );
            format!(
                "{}/B{batch}\t{:016x}\t{:016x}\n",
                r.system,
                r.tpot_mean.to_bits(),
                r.tpot_p99.to_bits()
            )
        })
        .concat()
    };
    let serial = run(1, 1);
    for chunk in [1usize, 3, grid] {
        assert_eq!(serial, run(2, chunk), "chunk={chunk} threads=2");
        assert_eq!(serial, run(4, chunk), "chunk={chunk} threads=4");
    }
    // resolve_chunk: explicit wins, zero falls through, auto ≥ 1.
    assert_eq!(sweep::resolve_chunk(Some(3), grid, 4), 3);
    assert!(sweep::resolve_chunk(Some(0), grid, 4) >= 1);
    assert!(sweep::resolve_chunk(None, grid, 4) >= 1);
}

#[test]
fn scaling_signal_assembly_is_pure_across_thread_counts() {
    // The closed-loop contract: a ScalingSignal is a pure function of
    // sim state — assembling one (and digesting it into a decision-cache
    // key) on a sweep worker must be bit-identical no matter how many
    // workers run or which worker claims the cell.
    use janus::scaling::ScalingSignal;
    let signal_for = |cell: u64| -> ScalingSignal {
        let mut rng = Rng::seed_from_u64(split_seed(0x51C9, cell));
        let mut sig = ScalingSignal::idle(60.0);
        sig.envelope_demand = rng.f64() * 500.0;
        sig.measured_demand = rng.f64() * 500.0;
        sig.backlog_tokens = rng.f64() * 4096.0;
        sig.kv_utilization = rng.f64();
        sig.queue_occupancy = rng.f64();
        sig.preemptions = rng.next_u64() % 64;
        sig.rejections = rng.next_u64() % 64;
        sig.tpot_targets[0] = Some(0.05 + rng.f64() * 0.1);
        sig.class_active = [true, rng.f64() < 0.5, false];
        sig
    };
    let cells: Vec<u64> = (0..24).collect();
    let run = |threads: usize| -> Vec<(u64, u64, u64)> {
        sweep(&cells, threads, |_, &c| {
            let sig = signal_for(c);
            (
                sig.fingerprint(),
                sig.planned_demand().to_bits(),
                sig.effective_slo(Slo::from_ms(200.0)).tpot.to_bits(),
            )
        })
    };
    let serial = run(1);
    // Distinct inputs digest distinctly (the cache key lane is live).
    assert!(serial.windows(2).all(|w| w[0].0 != w[1].0));
    for threads in [2usize, 4, 8] {
        assert_eq!(serial, run(threads), "threads={threads}");
    }
}

#[test]
fn trace_bytes_are_byte_identical_across_thread_counts() {
    // The observability plane rides the same contract as the results:
    // the merged recorder is assembled in cell-submission order, so the
    // serialized Chrome-trace JSON and metrics TSV from the canonical
    // sample grid are byte-identical at every worker count — including
    // an oversubscribed one — and across reruns at the same seed.
    use janus::obs::ObsMode;
    use janus::sim::tracegen::sample_bundle;
    let serial = sample_bundle(ObsMode::Full, 1);
    assert!(!serial.trace_json.is_empty());
    assert!(serial.results.iter().all(|c| c.outcome.is_ok()));
    let parallel = if sweep::hardware_threads() >= 4 { 4 } else { 2 };
    for threads in [2usize, parallel, 64] {
        let run = sample_bundle(ObsMode::Full, threads);
        assert_eq!(
            serial.trace_json, run.trace_json,
            "trace bytes drifted at threads={threads}"
        );
        assert_eq!(
            serial.metrics_tsv, run.metrics_tsv,
            "metrics bytes drifted at threads={threads}"
        );
    }
    let rerun = sample_bundle(ObsMode::Full, 1);
    assert_eq!(serial.trace_json, rerun.trace_json, "rerun drifted");
    assert_eq!(serial.metrics_tsv, rerun.metrics_tsv, "rerun drifted");
    // Counters mode shares the byte-identity claim for its TSV (its
    // event stream is empty by construction).
    let c1 = sample_bundle(ObsMode::Counters, 1);
    let c4 = sample_bundle(ObsMode::Counters, parallel);
    assert_eq!(c1.metrics_tsv, c4.metrics_tsv);
    assert_eq!(c1.trace_json, "[\n\n]\n", "counters mode buffered events");
}

#[test]
fn janus_threads_env_is_parsed_not_trusted_blindly() {
    // resolve_threads: explicit wins over everything and is clamped to
    // ≥ 1; the environment fallback path is covered by the CI matrix
    // (JANUS_THREADS=2 / unset), not mutated here — tests share one
    // process environment.
    assert_eq!(sweep::resolve_threads(Some(7)), 7);
    assert!(sweep::resolve_threads(Some(0)) >= 1);
    assert!(sweep::resolve_threads(None) >= 1);
}
