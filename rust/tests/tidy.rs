//! The repo's own static-analysis gate, run as a tier-1 test.
//!
//! `repo_is_tidy` is the load-bearing case: it scans `rust/src` +
//! `rust/tests` with the same `analysis::run_repo_scan` the `tidy` bin
//! uses and fails on any violation or unused suppression, so the
//! invariants in DESIGN.md §"Static invariants" bind on every `cargo
//! test`, not just in the CI tidy job. The remaining cases feed
//! synthetic fixtures through the full `scan_sources` pipeline to prove
//! each rule is live end-to-end (the per-rule unit tests exercise the
//! matchers; these pin the wiring).
//!
//! Fixture sources live in raw strings: the scanner masks string
//! contents before any rule runs, so the violating tokens below never
//! fire on this file during the self-scan.

use janus::analysis::{run_repo_scan, scan_sources, SourceFile};

/// Lex one fixture and scan it (no DESIGN.md — env table drift is
/// exercised separately).
fn scan_one(rel_path: &str, text: &str) -> janus::analysis::Report {
    scan_sources(&[SourceFile::lex(rel_path, text)], None)
}

#[test]
fn repo_is_tidy() {
    let report = run_repo_scan().expect("walking rust/src + rust/tests");
    assert!(
        report.is_clean(),
        "tidy violations in the repo:\n{}",
        report.render()
    );
}

#[test]
fn seeded_wallclock_violation_is_caught() {
    let report = scan_one(
        "src/sim/engine.rs",
        r#"
pub fn now_seconds() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
"#,
    );
    assert_eq!(report.count_rule("no-wallclock"), 1, "{}", report.render());
}

#[test]
fn seeded_unordered_iter_violation_is_caught() {
    let report = scan_one(
        "src/sim/engine.rs",
        r#"
use std::collections::HashMap;
pub fn total(m: &HashMap<u32, f64>) -> f64 {
    let mut sum = 0.0;
    for (_, v) in m.iter() {
        sum += v;
    }
    sum
}
"#,
    );
    assert_eq!(
        report.count_rule("no-unordered-iter"),
        1,
        "{}",
        report.render()
    );
}

#[test]
fn seeded_nan_order_violation_is_caught() {
    let report = scan_one(
        "src/util/stats.rs",
        r#"
pub fn sort(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#,
    );
    assert_eq!(report.count_rule("no-nan-order"), 1, "{}", report.render());
}

#[test]
fn seeded_panic_violation_is_caught() {
    let report = scan_one(
        "src/workload/trace.rs",
        r#"
pub fn first(xs: &[f64]) -> f64 {
    *xs.first().unwrap()
}
"#,
    );
    assert_eq!(
        report.count_rule("no-panic-in-lib"),
        1,
        "{}",
        report.render()
    );
}

#[test]
fn seeded_hot_path_alloc_violation_is_caught() {
    let report = scan_one(
        "src/scheduler/aebs.rs",
        r#"
pub fn step() -> Vec<u32> {
    // tidy:hot-path:begin
    let out = Vec::new();
    // tidy:hot-path:end
    out
}
"#,
    );
    assert_eq!(
        report.count_rule("no-alloc-in-hot-path"),
        1,
        "{}",
        report.render()
    );
}

#[test]
fn seeded_env_violation_is_caught() {
    // Assembled at runtime so the name never appears as a literal in
    // this file (the self-scan reads string contents for env names).
    let bogus = ["JANUS", "BOGUS"].join("_");
    let src = format!(
        r#"
pub fn knob() -> bool {{
    std::env::var("{bogus}").is_ok()
}}
"#
    );
    let report = scan_one("src/sim/engine.rs", &src);
    assert_eq!(report.count_rule("env-registry"), 1, "{}", report.render());
}

#[test]
fn suppression_silences_and_unused_suppression_errors() {
    let suppressed = scan_one(
        "src/workload/trace.rs",
        r#"
pub fn first(xs: &[f64]) -> f64 {
    // tidy:allow(no-panic-in-lib): caller guarantees non-empty
    *xs.first().unwrap()
}
"#,
    );
    assert!(suppressed.is_clean(), "{}", suppressed.render());

    let unused = scan_one(
        "src/workload/trace.rs",
        r#"
// tidy:allow(no-panic-in-lib): nothing here panics
pub fn id(x: f64) -> f64 {
    x
}
"#,
    );
    assert_eq!(
        unused.count_rule("unused-suppression"),
        1,
        "{}",
        unused.render()
    );
}

#[test]
fn violation_lines_render_as_file_line_rule() {
    let report = scan_one(
        "src/util/stats.rs",
        r#"
pub fn sort(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#,
    );
    let rendered = report.render();
    assert!(
        rendered.contains("src/util/stats.rs:3: no-nan-order:"),
        "rendered:\n{rendered}"
    );
}
