//! Minimal vendored subset of the `anyhow` API.
//!
//! The build environment has no crates.io access, so this crate provides
//! the slice of `anyhow` the repository actually uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`] macros, and the [`Context`]
//! extension trait with `context`/`with_context` on `Result` and `Option`.
//! Semantics match the real crate for these paths: errors carry a chain of
//! context messages, `{:#}` formatting prints the chain joined by ": ",
//! and any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically-typed error with a chain of context messages.
pub struct Error {
    /// Outermost message first (most recent context), root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Build from a standard error, preserving its source chain.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost→innermost message chain.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` does not implement `std::error::Error`
// (that is what allows the blanket `From` below without coherence
// conflicts).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result<T, anyhow::Error>` with a defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `context`/`with_context` to `Result` and
/// `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x.bin")).unwrap_err();
        assert_eq!(format!("{e}"), "reading x.bin");
        assert_eq!(format!("{e:#}"), "reading x.bin: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        let name = "w1";
        let e = anyhow!("weight '{name}' not found");
        assert_eq!(e.to_string(), "weight 'w1' not found");
        let e2 = anyhow!("shape {:?} != len {}", vec![2, 2], 5);
        assert_eq!(e2.to_string(), "shape [2, 2] != len 5");
        fn bails() -> Result<()> {
            bail!("bad magic at {}", 7);
        }
        assert_eq!(bails().unwrap_err().to_string(), "bad magic at 7");
    }

    #[test]
    fn debug_format_lists_causes() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
    }
}
