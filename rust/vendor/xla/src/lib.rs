//! Compile-only stub of the XLA/PJRT Rust bindings.
//!
//! The offline build environment ships neither the XLA C++ toolchain nor
//! the `xla` bindings crate, so this stub mirrors exactly the API surface
//! `janus::runtime` consumes. Host-side literal handling (`Literal::vec1`,
//! `reshape`, `to_vec`, `scalar`) is implemented for real — the
//! literal-utility unit tests exercise it — while every operation that
//! would require a PJRT plugin (`HloModuleProto::from_text_file`,
//! `PjRtClient::compile`) returns [`Error::Unavailable`]. Runtime tests
//! skip gracefully when artifacts are missing, so the suite stays green;
//! swap this path dependency for the real bindings to execute artifacts.

use std::fmt;

/// Stub error type.
#[derive(Debug)]
pub enum Error {
    /// The operation needs a real PJRT backend.
    Unavailable(&'static str),
    /// Host-side literal misuse (shape/type mismatch).
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what} requires the real XLA/PJRT bindings (this build uses the vendored stub)"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a stub literal can hold.
#[derive(Clone, Debug, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side literal: typed data plus a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Types storable in a [`Literal`].
pub trait NativeType: Sized + Copy {
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(payload: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(payload: &Payload) -> Option<Vec<Self>> {
        match payload {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(payload: &Payload) -> Option<Vec<Self>> {
        match payload {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            payload: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal {
            dims: Vec::new(),
            payload: T::wrap(vec![value]),
        }
    }

    fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::Literal(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| Error::Literal("element type mismatch in to_vec".to_string()))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come from executions, which the stub cannot run), so a plain
    /// literal decomposes to itself — enough for type-checking callers.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Ok(vec![self.clone()])
    }
}

/// Parsed HLO module (never constructible through the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle returned by executions.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal inputs; one result buffer list per device.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. The stub "CPU client" constructs (so code that
/// only builds an engine works), but compilation is unavailable.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            platform: "stub-cpu",
        })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn scalar_has_rank_zero() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let comp = XlaComputation { _private: () };
        assert!(c.compile(&comp).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
